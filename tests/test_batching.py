"""Batched multi-trajectory estimation: batched == looped ``map_estimate``
(linear + nonlinear), exact length-padding, ragged bucketing, and the
jit-executable cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import coordinated_turn, wiener_velocity
from repro.core import (
    bucket_length,
    cache_stats,
    map_estimate,
    map_estimate_batched,
    map_estimate_ragged,
    pad_record,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)

NSUB = 5


def _linear_batch(B=3, T=4, seed=0):
    model = wiener_velocity()
    ts = time_grid(0.0, 1.0, T * NSUB)
    ys = jnp.stack([simulate_linear(model, ts, jax.random.PRNGKey(seed + i))[1]
                    for i in range(B)])
    return model, ts, ys


def _nonlinear_batch(B=3, T=4, seed=10):
    model = coordinated_turn()
    ts = time_grid(0.0, 1.0, T * NSUB)
    ys = jnp.stack(
        [simulate_nonlinear(model, ts, jax.random.PRNGKey(seed + i))[1]
         for i in range(B)])
    return model, ts, ys


@pytest.mark.parametrize("method", ["parallel_rts", "sequential_rts"])
def test_linear_batched_matches_loop(method):
    model, ts, ys = _linear_batch()
    sol = map_estimate_batched(model, ts, ys, method=method, nsub=NSUB,
                               mode="discrete")
    assert sol.x.shape == (ys.shape[0], ys.shape[1] + 1, model.nx)
    for i in range(ys.shape[0]):
        ref = map_estimate(model, ts, ys[i], method=method, nsub=NSUB,
                           mode="discrete")
        np.testing.assert_allclose(sol.x[i], ref.x, atol=1e-6, rtol=0)
        np.testing.assert_allclose(sol.S[i], ref.S, atol=1e-6, rtol=0)


@pytest.mark.parametrize("method", ["parallel_rts", "sequential_rts"])
def test_nonlinear_batched_matches_loop(method):
    model, ts, ys = _nonlinear_batch()
    sol = map_estimate_batched(model, ts, ys, method=method, nsub=NSUB,
                               mode="euler", iterations=3)
    for i in range(ys.shape[0]):
        ref = map_estimate(model, ts, ys[i], method=method, nsub=NSUB,
                           mode="euler", iterations=3)
        np.testing.assert_allclose(sol.x[i], ref.x, atol=1e-6, rtol=0)


def test_batched_per_record_time_grids():
    """ts may be (B, N+1): records sharing N but not the grid itself."""
    model = wiener_velocity()
    N = 4 * NSUB
    ts_b = jnp.stack([time_grid(0.0, 1.0 + 0.5 * i, N) for i in range(2)])
    ys = jnp.stack([simulate_linear(model, ts_b[i],
                                    jax.random.PRNGKey(20 + i))[1]
                    for i in range(2)])
    sol = map_estimate_batched(model, ts_b, ys, method="parallel_rts",
                               nsub=NSUB, mode="discrete")
    for i in range(2):
        ref = map_estimate(model, ts_b[i], ys[i], method="parallel_rts",
                           nsub=NSUB, mode="discrete")
        np.testing.assert_allclose(sol.x[i], ref.x, atol=1e-8, rtol=0)


def test_masked_padding_is_exact():
    """A masked tail beyond t_f must leave the real window unchanged."""
    model, ts, ys = _linear_batch(B=1)
    N = ys.shape[1]
    ts_p, y_p, mask = pad_record(np.asarray(ts), np.asarray(ys[0]),
                                 N + 3 * NSUB)
    ref = map_estimate(model, ts, ys[0], method="parallel_rts", nsub=NSUB,
                       mode="discrete")
    sol = map_estimate(model, jnp.asarray(ts_p), jnp.asarray(y_p),
                       method="parallel_rts", nsub=NSUB, mode="discrete",
                       measurement_mask=jnp.asarray(mask))
    np.testing.assert_allclose(sol.x[:N + 1], ref.x, atol=1e-9, rtol=0)
    np.testing.assert_allclose(sol.S[:N + 1], ref.S, atol=1e-9, rtol=0)


def test_bucket_length_rules():
    assert bucket_length(1, 5) == 5
    assert bucket_length(5, 5) == 5
    assert bucket_length(6, 5) == 10
    assert bucket_length(11, 5) == 20
    assert bucket_length(95, 10) == 160
    assert bucket_length(7, 5, bucket_sizes=[10, 40]) == 10
    assert bucket_length(11, 5, bucket_sizes=[10, 40]) == 40
    with pytest.raises(ValueError):
        bucket_length(50, 5, bucket_sizes=[10, 40])
    with pytest.raises(ValueError):
        bucket_length(7, 5, bucket_sizes=[12])   # not a multiple of nsub


def test_pad_record_shapes_and_grid():
    ts = np.linspace(0.0, 1.0, 11)
    y = np.ones((10, 2))
    ts_p, y_p, mask = pad_record(ts, y, 15)
    assert ts_p.shape == (16,) and y_p.shape == (15, 2)
    np.testing.assert_allclose(np.diff(ts_p), 0.1, atol=1e-12)
    assert mask.tolist() == [1.0] * 10 + [0.0] * 5


def test_ragged_matches_individual_solves():
    model = wiener_velocity()
    lengths = [12, 20, 35]          # buckets: 20, 20, 40 (nsub=5)
    records = []
    for i, N in enumerate(lengths):
        ts_i = time_grid(0.0, N / 20.0, N)
        _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(30 + i))
        records.append((np.asarray(ts_i), np.asarray(y_i)))
    sols = map_estimate_ragged(model, records, method="parallel_rts",
                               nsub=NSUB, mode="discrete")
    assert [s.x.shape[0] for s in sols] == [n + 1 for n in lengths]
    for (ts_i, y_i), sol in zip(records, sols):
        # reference: the nsub-free sequential solver on the UNPADDED record
        # (12 and 35 are not multiples of nsub -- only bucketing makes them
        # parallel-solvable); discrete mode is exact, so agreement is tight.
        ref = map_estimate(model, jnp.asarray(ts_i), jnp.asarray(y_i),
                           method="sequential_rts", mode="discrete")
        np.testing.assert_allclose(sol.x, ref.x, atol=1e-6, rtol=0)


def test_executable_cache_reuse():
    model, ts, ys = _linear_batch(B=2, seed=40)
    kwargs = dict(method="parallel_rts", nsub=NSUB, mode="discrete")
    map_estimate_batched(model, ts, ys, **kwargs)
    before = cache_stats()
    map_estimate_batched(model, ts, ys * 2.0, **kwargs)   # same shapes
    after = cache_stats()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    # a new shape compiles a new executable
    map_estimate_batched(model, ts, ys[:1], **kwargs)
    assert cache_stats()["misses"] == before["misses"] + 1


def test_method_registry_dispatch():
    from repro.core import get_solver, method_names, register_method
    from repro.core.sequential import sequential_rts

    assert {"parallel_rts", "parallel_two_filter", "sequential_rts",
            "sequential_two_filter"} <= set(method_names())
    with pytest.raises(ValueError):
        get_solver("no_such_method")

    register_method("_test_seq_rts",
                    lambda g, nsub, mode: sequential_rts(g, mode),
                    overwrite=True)
    model, ts, ys = _linear_batch(B=1, seed=60)
    sol = map_estimate(model, ts, ys[0], method="_test_seq_rts",
                       mode="discrete")
    ref = map_estimate(model, ts, ys[0], method="sequential_rts",
                       mode="discrete")
    np.testing.assert_allclose(sol.x, ref.x, atol=1e-12, rtol=0)
    with pytest.raises(ValueError):              # no silent overwrite
        register_method("_test_seq_rts", lambda g, n, m: None)


def test_batched_input_validation():
    model, ts, ys = _linear_batch(B=2, seed=50)
    with pytest.raises(ValueError):
        map_estimate_batched(model, ts, ys[0])            # missing batch axis
    with pytest.raises(ValueError):
        map_estimate_batched(model, ts[:-1], ys)          # N mismatch
    with pytest.raises(ValueError):
        map_estimate_batched(model, ts, ys,
                             measurement_mask=jnp.ones((2, 3)))
    with pytest.raises(ValueError):
        map_estimate_batched(model, ts, ys, method="no_such_method")
