"""Logical-axis sharding rules (DP/TP/EP/SP) with divisibility fallback.

Parameters and activations are annotated with LOGICAL axis names
("embed", "heads", "ff", "vocab", "experts", ...).  ``choose_pspec`` maps a
logical shape to a concrete ``PartitionSpec`` for the active mesh:

* exactly one tensor dimension is model-sharded, picked by walking
  ``MODEL_PRIORITY`` and taking the first logical axis that is present AND
  whose size is divisible by the mesh's model-axis size (llava's 56 q-heads
  do not divide 16 -> falls through to the 128 head_dim; granite's 40
  experts fall through to d_ff);
* the "batch" axis shards over ("pod", "data") (the pod axis is folded into
  data parallelism);
* optimizer-state tensors may additionally shard their largest replicated
  dimension over "data" (ZeRO-1), handled in ``train/optimizer.py``.

``logical_constraint`` applies ``with_sharding_constraint`` when called
under an active mesh context and is a no-op otherwise, so model code is
mesh-agnostic and single-device tests run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority of logical axes for the single model-sharded dimension
MODEL_PRIORITY: Sequence[str] = (
    "experts", "vocab", "ff", "heads", "kv_heads", "ssm_inner", "ssm_x",
    "ssm_heads", "head", "embed_model",
)

# logical axes that shard over the data (+pod) axes
BATCH_AXES = ("batch",)

# logical axes that may shard over data for sequence parallelism (opt-in)
SEQ_AXES = ("seq_sp",)


class _MeshContext(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.data_axes: tuple = ("data",)
        self.model_axis: str = "model"
        self.tp_exclude: frozenset = frozenset()


_CTX = _MeshContext()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, *, batch_axes: tuple = None,
                 tp_exclude=()):
    """Activate logical->physical rules for ``mesh``.

    Meshes with a "pod" axis fold it into the batch sharding.

    ``batch_axes`` overrides the mesh axes used for batch/zero1 sharding
    (e.g. ("pod", "data", "model") for the dp-only policy on small
    models); ``tp_exclude`` removes logical names from the model-sharding
    priority (e.g. everything but "vocab" under dp-only).
    """
    prev = (_CTX.mesh, _CTX.data_axes, _CTX.model_axis, _CTX.tp_exclude)
    _CTX.mesh = mesh
    axis_names = mesh.axis_names
    if batch_axes is not None:
        _CTX.data_axes = tuple(a for a in batch_axes if a in axis_names)
    else:
        _CTX.data_axes = tuple(a for a in ("pod", "data")
                               if a in axis_names)
    _CTX.model_axis = "model" if "model" in axis_names else None
    _CTX.tp_exclude = frozenset(tp_exclude)
    try:
        with mesh:
            yield mesh
    finally:
        (_CTX.mesh, _CTX.data_axes, _CTX.model_axis,
         _CTX.tp_exclude) = prev


def data_parallel_size(mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return 1
    return _axis_size(mesh, tuple(_CTX.data_axes)) if _CTX.data_axes else 1


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, names) -> int:
    size = 1
    for n in names if isinstance(names, tuple) else (names,):
        size *= mesh.shape[n]
    return size


def choose_pspec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None) -> P:
    """Map logical axes to a PartitionSpec under the active mesh."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    assert len(shape) == len(logical), (shape, logical)
    entries: list = [None] * len(shape)

    # batch / ZeRO-1 axes -> the data axes, with progressive fallback to
    # fewer axes when the dimension does not divide the full product
    # (e.g. batch 256 on a 512-chip dp-only layout).
    for i, name in enumerate(logical):
        if name in BATCH_AXES + ("zero1",) and _CTX.data_axes:
            axes = tuple(_CTX.data_axes)
            while axes:
                if shape[i] % _axis_size(mesh, axes) == 0:
                    entries[i] = axes if len(axes) > 1 else axes[0]
                    break
                axes = axes[1:]

    def used_axes() -> set:
        out = set()
        for e in entries:
            if e is None:
                continue
            out.update(e if isinstance(e, tuple) else (e,))
        return out

    # sequence-parallel axis -> the model axis (megatron-style SP)
    if _CTX.model_axis is not None and _CTX.model_axis not in used_axes():
        msize = mesh.shape[_CTX.model_axis]
        for i, name in enumerate(logical):
            if name in SEQ_AXES and entries[i] is None \
                    and shape[i] % msize == 0:
                entries[i] = _CTX.model_axis
                break

    # one model-sharded dim by priority with divisibility fallback
    if _CTX.model_axis is not None and _CTX.model_axis not in used_axes():
        msize = mesh.shape[_CTX.model_axis]
        for cand in MODEL_PRIORITY:
            if cand in _CTX.tp_exclude:
                continue
            placed = False
            for i, name in enumerate(logical):
                if name == cand and entries[i] is None \
                        and shape[i] % msize == 0 and shape[i] >= msize:
                    entries[i] = _CTX.model_axis
                    placed = True
                    break
            if placed:
                break
    return P(*entries)


def logical_constraint(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = choose_pspec(x.shape, logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape, logical, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, choose_pspec(shape, logical, mesh))


def tree_pspecs(axes_tree, shapes_tree, mesh: Optional[Mesh] = None):
    """Map a tree of logical-axes tuples + shapes to PartitionSpecs."""
    mesh = mesh or _CTX.mesh
    return jax.tree_util.tree_map(
        lambda ax, shp: choose_pspec(shp, ax, mesh),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, shapes_tree, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    specs = tree_pspecs(axes_tree, shapes_tree, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def shard_over_batch(fn, mesh: Mesh, batch_axis: str,
                     arg_batched: Sequence[bool]):
    """Wrap a batched function so its leading batch axis spreads over
    ``mesh.shape[batch_axis]`` devices with ``shard_map``.

    ``arg_batched[i]`` marks whether positional arg ``i`` carries the batch
    axis (sharded) or is shared across requests (replicated).  Outputs are
    sharded over the batch axis.  This is the REQUEST-axis decomposition
    used by ``repro.core.batching`` / the ``TrajectoryEngine`` -- the
    complement of the time-axis ``core.pscan.distributed_scan``.
    """
    try:                                   # jax >= 0.6 top-level API
        from jax import shard_map
    except ImportError:                    # older releases
        from jax.experimental.shard_map import shard_map

    in_specs = tuple(P(batch_axis) if b else P() for b in arg_batched)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=P(batch_axis))
