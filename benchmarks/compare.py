"""Hillclimb comparison: baseline vs tagged variant roofline terms.

Usage:
  PYTHONPATH=src:. python -m benchmarks.compare --arch mamba2-370m \
      --shape train_4k [--mesh pod256]
Prints one row per tag found for the cell with the three terms, the
dominant term, and deltas vs the untagged baseline.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.flops import model_flops, step_cost  # noqa: E402
from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402


def cell_terms(rec, causal_skip=False, overrides=None):
    from repro.config import SHAPE_SUITE, get_config
    import dataclasses

    cfg = get_config(rec["arch"])
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = (str(v).lower() in ("1", "true", "yes")
                        if isinstance(cur, bool) else type(cur)(v))
        cfg = dataclasses.replace(cfg, **typed)
    shape = next(s for s in SHAPE_SUITE if s.name == rec["shape"])
    chips = rec["num_devices"]
    cost = step_cost(cfg, shape, chips, causal_skip=causal_skip)
    mf = model_flops(cfg, shape)

    coll = rec["collectives"]["total_bytes"]
    hlo_path = rec.get("hlo_path")
    if hlo_path and os.path.exists(hlo_path):
        from repro.launch.hlo_parse import collective_analysis, load_hlo
        wa = collective_analysis(load_hlo(hlo_path))
        coll = wa["total_wire_bytes"]
        detail = wa["wire_bytes"]
    else:
        detail = rec["collectives"]["bytes"]
    t = {
        "compute": cost.flops / (chips * PEAK_FLOPS),
        "memory": cost.hbm_bytes / HBM_BW,
        "collective": coll / LINK_BW,
    }
    lb = max(t.values())
    return {
        **t, "dominant": max(t, key=t.get),
        "roofline_frac": mf / (chips * PEAK_FLOPS * lb),
        "coll_detail_gb": {k: round(v / 1e9, 1) for k, v in detail.items()
                           if v},
        "mem_gb": (rec["memory_analysis"]["argument_size_in_bytes"]
                   + rec["memory_analysis"]["temp_size_in_bytes"]) / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod256")
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()

    pattern = os.path.join(
        args.dir, f"{args.mesh}--{args.arch}--{args.shape}*.json")
    base = None
    rows = []
    for path in sorted(glob.glob(pattern)):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        tag = rec.get("tag", "") or "baseline"
        causal_skip = tag in ("Q2", "Q3", "S2") or "cskip" in tag
        terms = cell_terms(rec, causal_skip=causal_skip,
                           overrides=rec.get("overrides"))
        rows.append((tag, terms))
        if tag == "baseline":
            base = terms

    for tag, t in rows:
        d = ""
        if base is not None and tag != "baseline":
            d = (f"  Δcoll {t['collective'] / base['collective'] - 1:+.0%}"
                 f"  Δfrac {t['roofline_frac'] / base['roofline_frac']:.2f}x")
        print(f"{tag:10s} comp {t['compute']:.3e}  mem {t['memory']:.3e}  "
              f"coll {t['collective']:.3e}  dom={t['dominant']:10s} "
              f"frac={t['roofline_frac']:.3f}  devGB={t['mem_gb']:.1f}{d}")
        print(f"           colls: {t['coll_detail_gb']}")


if __name__ == "__main__":
    main()
