"""Version shims for the Pallas TPU API surface.

The kernels are written against the current Pallas names; this module pins
the aliases that moved between JAX releases so the same kernel source runs
on every JAX this repo supports (>= 0.4.30):

* ``CompilerParams``: ``jax.experimental.pallas.tpu`` exposed the TPU
  compiler-parameter struct as ``TPUCompilerParams`` up to ~0.4.x and
  renamed it to ``CompilerParams`` later.  Same fields either way
  (``dimension_semantics`` is all we use).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None)
if CompilerParams is None:  # pragma: no cover - depends on jax version
    CompilerParams = _pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
