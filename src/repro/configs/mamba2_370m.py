"""mamba2-370m: 48L d_model=1024, attention-free SSD, vocab=50280,
ssm_state=128 [arXiv:2405.21060].

The SSD layer runs on the paper's affine-scan machinery (DESIGN.md S3);
long_500k decode is O(1)-state.
"""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=0, vocab_size=50280, mlp_type="none", mixer="ssm",
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        tie_embeddings=True, remat_group=8)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="mamba2-370m-smoke", num_layers=2, d_model=64,
        vocab_size=128, ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
