"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m-smoke \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Runs on whatever devices the process has (CPU here, TPU pod in prod);
under multi-device meshes the step is jitted with the logical shardings
from ``repro.distributed.sharding`` (see train/trainer.py).
"""
from __future__ import annotations

import argparse

import jax

from repro.config import TrainConfig, get_config
from repro.train.data import LMDataPipeline
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        seq_len=args.seq, global_batch=args.batch,
        microbatches=args.microbatches, seed=args.seed,
        checkpoint_every=args.ckpt_every, log_every=args.log_every)
    pipeline = LMDataPipeline(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0)
    trainer = Trainer(cfg=cfg, tcfg=tcfg, pipeline=pipeline,
                      ckpt_dir=args.ckpt_dir)
    print(f"[train] arch={cfg.name} params={cfg.param_count():,} "
          f"devices={jax.device_count()}")
    trainer.run(args.steps)


if __name__ == "__main__":
    main()
