"""Associative combination operators (paper eqs. 42, 45-46, and the
value-application step used for within-block interior fills).

All operators broadcast over arbitrary leading batch axes: ``A @ B`` and
``jnp.linalg.solve`` batch over leading dimensions, so the same code path is
used for single pairs, vmapped blocks, and the Pallas kernel oracle
(``repro.kernels.lqt_combine.ref`` re-exports :func:`lqt_combine`).

Orientation convention: ``combine(e1, e2)`` composes ``e1`` on the EARLIER
(reversed-time) interval ``[s, gamma]`` with ``e2`` on ``[gamma, t]``,
exactly eq. (42) with ``1 -> (s, gamma)`` and ``2 -> (gamma, t)``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import AffineElement, LQTElement, ValueFn


def _sym(M: jnp.ndarray) -> jnp.ndarray:
    """Numerically symmetrise a (batched) matrix."""
    return 0.5 * (M + jnp.swapaxes(M, -1, -2))


def _eye_like(M: jnp.ndarray) -> jnp.ndarray:
    n = M.shape[-1]
    return jnp.broadcast_to(jnp.eye(n, dtype=M.dtype), M.shape)


def lqt_combine(e1: LQTElement, e2: LQTElement) -> LQTElement:
    """Eq. (42): min-plus composition of two conditional value functions.

    Uses two batched linear solves with ``M = I + C1 J2`` (and its transpose
    ``I + J2 C1 = M^T`` since C1, J2 are symmetric) instead of explicit
    inverses.  Outputs C and J are re-symmetrised to stop round-off drift.
    """
    A1, b1, C1, eta1, J1 = e1
    A2, b2, C2, eta2, J2 = e2

    I = _eye_like(C1)
    M = I + C1 @ J2                      # (..., nx, nx)
    Mt = jnp.swapaxes(M, -1, -2)         # = I + J2 C1

    # Right-hand sides solved against M:   M^{-1} [A1 | b1 + C1 eta2 | C1]
    rhs1 = jnp.concatenate(
        [A1, (b1 + (C1 @ eta2[..., None])[..., 0])[..., None], C1], axis=-1
    )
    sol1 = jnp.linalg.solve(M, rhs1)
    nx = A1.shape[-1]
    MiA1 = sol1[..., :nx]
    Mib = sol1[..., nx]
    MiC1 = sol1[..., nx + 1:]

    # Solved against M^T:   (I + J2 C1)^{-1} [eta2 - J2 b1 | J2 A1]
    rhs2 = jnp.concatenate(
        [(eta2 - (J2 @ b1[..., None])[..., 0])[..., None], J2 @ A1], axis=-1
    )
    sol2 = jnp.linalg.solve(Mt, rhs2)
    Mte = sol2[..., 0]
    MtJA = sol2[..., 1:]

    A1T = jnp.swapaxes(A1, -1, -2)
    A = A2 @ MiA1
    b = (A2 @ Mib[..., None])[..., 0] + b2
    C = _sym(A2 @ MiC1 @ jnp.swapaxes(A2, -1, -2) + C2)
    eta = (A1T @ Mte[..., None])[..., 0] + eta1
    J = _sym(A1T @ MtJA + J1)
    return LQTElement(A, b, C, eta, J)


def affine_combine(e1: AffineElement, e2: AffineElement) -> AffineElement:
    """Eqs. (45)-(46): compose phi -> Phi2 (Phi1 phi + beta1) + beta2.

    ``e1`` maps over the earlier interval, ``e2`` over the later one.
    """
    Phi = e2.Phi @ e1.Phi
    beta = (e2.Phi @ e1.beta[..., None])[..., 0] + e2.beta
    return AffineElement(Phi, beta)


def apply_element_to_value(e: LQTElement, vf: ValueFn) -> ValueFn:
    """Fold a one-interval element into a terminal value function.

    Computes the (J, eta) block of ``lqt_combine(e, value_as_element)``:

        S' = A^T (I + S C)^{-1} S A + J
        v' = A^T (I + S C)^{-1} (v - S b) + eta

    i.e. one information-form Kalman-Bucy step backwards in reversed time
    (equivalently one filter step forwards in original time).  Cheaper than
    the full 5-tuple combine; used for within-block interior value fills.
    """
    A, b, C, eta, J = e
    S2, v2 = vf
    I = _eye_like(C)
    Mt = I + S2 @ C  # (I + J2 C1) with J2 = S2, C1 = C
    rhs = jnp.concatenate(
        [(v2 - (S2 @ b[..., None])[..., 0])[..., None], S2 @ A], axis=-1
    )
    sol = jnp.linalg.solve(Mt, rhs)
    At = jnp.swapaxes(A, -1, -2)
    v = (At @ sol[..., 0][..., None])[..., 0] + eta
    S = _sym(At @ sol[..., 1:] + J)
    return ValueFn(S, v)


def value_as_element(vf: ValueFn) -> LQTElement:
    """Embed a terminal value function as a scan element (section 3.4).

    The terminal element ``a_T`` has A = 0, b = 0 and carries the prior in
    (J, eta).  With A = 0 the C entry of any combined range containing a_T
    never feeds a subsequent combine (a_T is always rightmost), so the
    kappa -> infinity boundary of eq. (34) can be represented with C = 0;
    see DESIGN.md S1 and the associativity tests.
    """
    S, v = vf
    Z = jnp.zeros_like(S)
    z = jnp.zeros_like(v)
    return LQTElement(Z, z, Z, v, S)


def elem_min_initial(e0: LQTElement, jitter: float = 0.0) -> LQTElement:
    """Eq. (50): fold the free-initial-condition element ``e`` (eq. 49,
    kappa -> infinity) into the first element: ``a0_bar = e (x) a0``.

    Requires J0 invertible; an optional diagonal ``jitter`` (scaled by the
    mean diagonal of J0) regularises near-singular first blocks.
    """
    A0, b0, C0, eta0, J0 = e0
    nx = A0.shape[-1]
    I = jnp.eye(nx, dtype=A0.dtype)
    if jitter:
        scale = jnp.trace(J0) / nx
        J0 = J0 + (jitter * scale) * I
    sol = jnp.linalg.solve(J0, jnp.concatenate([eta0[..., None], jnp.swapaxes(A0, -1, -2)], axis=-1))
    J0ie = sol[..., 0]
    J0iA0T = sol[..., 1:]
    Abar = jnp.zeros_like(A0)
    bbar = b0 + (A0 @ J0ie[..., None])[..., 0]
    Cbar = _sym(A0 @ J0iA0T + C0)
    return LQTElement(Abar, bbar, Cbar, eta0, J0)
