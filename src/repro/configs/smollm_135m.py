"""smollm-135m: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M] -- llama-arch small, tied embeddings."""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
        d_ff=1536, vocab_size=49152, tie_embeddings=True, remat_group=6)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="smollm-135m-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)
