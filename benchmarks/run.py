"""Benchmark harness entry point -- one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV lines; with ``--json PATH`` also
writes the schema-versioned ``BENCH_<name>.json`` artifact (rows + RNG
seeds + environment fingerprint + the full ``repro.obs`` snapshot --
cache hit/miss, compile seconds, solve-phase spans, engine latency
percentiles, padding waste).  CI's ``bench-baseline`` job runs
``--smoke --json BENCH_smoke.json`` and diffs the artifact against the
committed ``benchmarks/baselines/BENCH_seed.json`` with
``benchmarks/compare.py`` (see docs/OBSERVABILITY.md).

  fig1/*    paper Fig. 1  (linear Wiener velocity, seq vs parallel)
  fig2/*    paper Fig. 2  (coordinated-turn iterated MAP)
  nonlin/*  linearisation strategies (taylor vs sigma-point SLR):
            per-iteration wall time + final OM cost
  kern/*    kernel micro-benchmarks
  batch/*   request-axis throughput (problems/sec vs batch size)
  serve/*   TrajectoryEngine tracks/sec + latency percentiles
  stream/*  StreamingEngine window latency + tracks/sec: fixed-lag
            in-order, 10% late pushes through the reorder-slack path
            (merge/drop accounting), and adaptive-lag self-tuning
  dist/*    method="distributed" weak/strong scaling (subprocess with
            forced host devices -- this process's device count is locked)

``--fast`` shrinks the sweeps (CI-sized); ``--smoke`` shrinks further to
bit-rot-check sizes (every section runs in seconds); default runs the full
grids.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# fixed RNG seeds per section -- recorded into the JSON artifact so every
# number is reproducible from the file alone
SEEDS = {"fig1": 0, "fig2": 1, "nonlin": 3, "kern": 0, "batch": 0,
         "serve": 0, "stream": 0, "dist": 0}


def _dist_rows(smoke: bool) -> list:
    """Run benchmarks/distributed_scaling.py in a subprocess (XLA's forced
    host-device count locks at first jax init, so the 8-device sweep
    cannot run in this process) and parse its --emit-rows output."""
    import json
    import os
    import subprocess

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.setdefault("REPRO_BENCH_DEVICES", "8")
    cmd = [sys.executable,
           str(Path(__file__).resolve().parent / "distributed_scaling.py"),
           "--emit-rows"] + (["--smoke"] if smoke else [])
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(
            f"distributed_scaling subprocess failed:\n{out.stderr[-4000:]}")
    return [json.loads(line) for line in out.stdout.splitlines()
            if line.strip().startswith("{")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: CI bit-rot check for every section")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig2,nonlin,kern,batch,serve,"
                         "stream,dist")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the BENCH_<name>.json artifact here "
                         "(CI: BENCH_smoke.json)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import repro.obs as obs
    obs.enable()
    obs.reset()

    rows = []
    from benchmarks import (
        batch_throughput, engine_latency, fig1_linear, fig2_nonlinear,
        kernels_bench, nonlinear_linearization, streaming_latency,
    )
    if only is None or "fig1" in only:
        if args.smoke:
            rows += fig1_linear.run(T_list=(16,), repeats=1)
        else:
            rows += fig1_linear.run(
                T_list=(128, 256) if args.fast
                else (128, 256, 512, 1024, 2048),
                repeats=3 if args.fast else 5)
    if only is None or "fig2" in only:
        if args.smoke:
            rows += fig2_nonlinear.run(T_list=(16,), repeats=1, iterations=2)
        else:
            rows += fig2_nonlinear.run(
                T_list=(64, 128) if args.fast else (64, 128, 256, 512),
                repeats=2 if args.fast else 5)
    if only is None or "nonlin" in only:
        if args.smoke:
            rows += nonlinear_linearization.run(smoke=True)
        else:
            rows += nonlinear_linearization.run(
                T_list=(64,) if args.fast else (64, 256),
                repeats=2 if args.fast else 3)
    if only is None or "kern" in only:
        rows += kernels_bench.run(smoke=args.smoke)
    if only is None or "batch" in only:
        rows += batch_throughput.run(smoke=args.smoke or args.fast)
    if only is None or "serve" in only:
        rows += engine_latency.run(smoke=args.smoke or args.fast)
    if only is None or "stream" in only:
        rows += streaming_latency.run(smoke=args.smoke or args.fast)
    if only is None or "dist" in only:
        rows += _dist_rows(smoke=args.smoke or args.fast)

    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")

    if args.json:
        name = "smoke" if args.smoke else ("fast" if args.fast else "full")
        record = obs.bench_record(name, rows, seeds=SEEDS)
        path = obs.write_bench_json(args.json, record)
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
