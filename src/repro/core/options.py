"""Solver-owned option dataclasses for the unified estimation surface.

Every registered method declares the options IT understands as a frozen
dataclass registered alongside the solver
(:func:`repro.core.registry.register_method`), so knobs stop being
universal keyword soup on every entry point:

* :class:`SequentialOptions` -- ``mode`` only (sequential smoothers have no
  block structure);
* :class:`ParallelOptions` -- ``mode`` + ``nsub`` (blocks of ``nsub``
  substeps feed the associative scan);
* :class:`TwoFilterOptions` -- parallel options + the two-filter-specific
  ``block0_fill`` / ``tf_fill`` / ``jitter`` knobs of
  :func:`repro.core.parallel.parallel_two_filter`;
* :class:`KernelOptions` -- parallel options + the Pallas-kernel knobs of
  the ``parallel_kernel`` method (``block_size`` lanes per kernel grid
  step, ``interpret`` tri-state with automatic non-TPU fallback,
  ``precision`` compute dtype of the kernel scan);
* :class:`DistributedOptions` -- parallel options + the time-axis-sharding
  knobs of the ``distributed`` method (``time_axis`` / ``batch_axes`` mesh
  axis names, ``devices_per_time``, ``carry_dtype`` of the redundant carry
  scan, ``fallback`` behaviour below 2 shards);
* :class:`IteratedOptions` -- the iterated-linearisation (nonlinear) layer:
  ``iterations`` / ``divergence_correction`` / ``linearization`` plus the
  ``inner`` linear options forwarded to the method that solves each
  linearised subproblem;
* :class:`SigmaPointOptions` -- the ``sigma_point`` method (iterated
  posterior-linearisation smoother): :class:`IteratedOptions` with a
  sigma-point SLR default linearisation and an ``inner_method`` naming the
  linear solver backend each linearised subproblem runs on.

Unknown option names fail at CONSTRUCTION time (``TypeError`` from the
dataclass ``__init__``); value errors (bad ``mode``, non-positive ``nsub``)
fail in ``__post_init__`` -- never deep inside a trace.  All option classes
are frozen and hashable, so an options instance is part of the executable
cache key of :class:`repro.core.estimator.Estimator`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

MODES = ("euler", "rk4", "discrete")


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """Base options shared by every grid solver.

    ``mode`` selects the element discretisation: ``"euler"`` / ``"rk4"``
    integrate the paper's ODEs (43) literally; ``"discrete"`` composes
    exact substep elements so parallel == sequential to round-off.
    """

    mode: str = "euler"

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(
                f"mode must be one of {MODES}, got {self.mode!r}")

    @classmethod
    def from_legacy(cls, **kwargs) -> "SolverOptions":
        """Build options from the legacy kwarg soup, keeping only the
        fields THIS options class declares (shim support)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in kwargs.items()
                      if k in names and v is not None})

    def replace(self, **changes) -> "SolverOptions":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class SequentialOptions(SolverOptions):
    """Options of the sequential RTS / two-filter smoothers."""


@dataclasses.dataclass(frozen=True)
class ParallelOptions(SolverOptions):
    """Options of the parallel (associative-scan) smoothers.

    ``nsub`` is the number of substeps per scan block (paper: n = 10); the
    grid length N must be a multiple of it (the ragged/bucketed paths
    guarantee this by padding).
    """

    nsub: int = 10

    def __post_init__(self) -> None:
        super().__post_init__()
        if not isinstance(self.nsub, int) or self.nsub < 1:
            raise ValueError(f"nsub must be a positive int, got {self.nsub!r}")


KERNEL_PRECISIONS = ("default", "float32", "float64")


@dataclasses.dataclass(frozen=True)
class KernelOptions(ParallelOptions):
    """Options of the kernel-backed parallel smoother (``parallel_kernel``).

    ``block_size`` is the lane count per Pallas grid step of the combine
    kernel (128-multiples feed full TPU VREG rows; the wrapper shrinks it
    automatically for small scans).  ``interpret=None`` resolves at solve
    time to ``True`` off-TPU (Pallas interpreter, bit-accurate semantics)
    and ``False`` on TPU (Mosaic); pass an explicit bool to force either.
    ``precision`` is the kernel compute dtype: ``"default"`` keeps the
    element dtype, ``"float32"``/``"float64"`` cast the lane-major scan
    (TPUs have no native f64 -- use ``"float32"`` there for x64 grids).
    """

    block_size: int = 512
    interpret: Optional[bool] = None
    precision: str = "default"

    def __post_init__(self) -> None:
        super().__post_init__()
        if not isinstance(self.block_size, int) or self.block_size < 8:
            raise ValueError(
                f"block_size must be an int >= 8, got {self.block_size!r}")
        if self.interpret is not None and not isinstance(self.interpret,
                                                         bool):
            raise ValueError(
                f"interpret must be None (auto) or a bool, "
                f"got {self.interpret!r}")
        if self.precision not in KERNEL_PRECISIONS:
            raise ValueError(
                f"precision must be one of {KERNEL_PRECISIONS}, "
                f"got {self.precision!r}")

    def resolve_interpret(self) -> bool:
        """The effective interpret flag: explicit bool wins; ``None`` means
        interpret everywhere except a real TPU backend (Mosaic compilation
        needs one)."""
        if self.interpret is not None:
            return self.interpret
        import jax

        return jax.default_backend() != "tpu"


CARRY_DTYPES = ("default", "float32", "float64")
FALLBACKS = ("auto", "error")


@dataclasses.dataclass(frozen=True)
class DistributedOptions(ParallelOptions):
    """Options of the time-axis-sharded parallel smoother (``distributed``).

    ``time_axis`` names the mesh axis the block scan is sharded over;
    ``batch_axes`` names the mesh axes the stacked/ragged batch dimension
    may be sharded over (intersected with the actual mesh axes at solve
    time, so the same options work on a time-only and a 2-D mesh).
    ``devices_per_time`` pins the time-shard count when building a default
    mesh (``None`` = all visible devices); an explicit/ambient mesh with a
    different ``time_axis`` extent is an error, not a silent reshard.
    ``carry_dtype`` is the dtype of the O(P)-sequential redundant scan over
    the all-gathered per-shard carries (``"default"`` keeps the element
    dtype).  ``fallback="auto"`` degrades to the single-device parallel
    scan when fewer than 2 time shards are available; ``"error"`` raises
    instead.
    """

    time_axis: str = "time"
    batch_axes: tuple = ("data",)
    devices_per_time: Optional[int] = None
    carry_dtype: str = "default"
    fallback: str = "auto"

    def __post_init__(self) -> None:
        super().__post_init__()
        if not isinstance(self.time_axis, str) or not self.time_axis:
            raise ValueError(
                f"time_axis must be a non-empty str, got {self.time_axis!r}")
        if isinstance(self.batch_axes, list):
            object.__setattr__(self, "batch_axes", tuple(self.batch_axes))
        if not isinstance(self.batch_axes, tuple) or not all(
                isinstance(a, str) and a for a in self.batch_axes):
            raise ValueError(
                f"batch_axes must be a tuple of non-empty axis names, "
                f"got {self.batch_axes!r}")
        if self.time_axis in self.batch_axes:
            raise ValueError(
                f"time_axis {self.time_axis!r} cannot also be a batch axis")
        if self.devices_per_time is not None and (
                not isinstance(self.devices_per_time, int)
                or self.devices_per_time < 1):
            raise ValueError(
                f"devices_per_time must be None or a positive int, "
                f"got {self.devices_per_time!r}")
        if self.carry_dtype not in CARRY_DTYPES:
            raise ValueError(
                f"carry_dtype must be one of {CARRY_DTYPES}, "
                f"got {self.carry_dtype!r}")
        if self.fallback not in FALLBACKS:
            raise ValueError(
                f"fallback must be one of {FALLBACKS}, got {self.fallback!r}")

    def resolve_carry_dtype(self):
        """The jnp dtype of the redundant carry scan, or ``None`` to keep
        the element dtype."""
        if self.carry_dtype == "default":
            return None
        import jax.numpy as jnp

        return jnp.dtype(self.carry_dtype)


@dataclasses.dataclass(frozen=True)
class TwoFilterOptions(ParallelOptions):
    """Parallel two-filter smoother options (see
    :func:`repro.core.parallel.parallel_two_filter` for semantics)."""

    block0_fill: str = "affine"
    tf_fill: str = "combine"
    jitter: float = 1e-9

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.block0_fill not in ("affine", "min_initial"):
            raise ValueError(
                f"block0_fill must be 'affine' or 'min_initial', "
                f"got {self.block0_fill!r}")
        if self.tf_fill not in ("combine", "hjb_euler"):
            raise ValueError(
                f"tf_fill must be 'combine' or 'hjb_euler', "
                f"got {self.tf_fill!r}")


@dataclasses.dataclass(frozen=True)
class IteratedOptions:
    """Options of the iterated-linearisation layer (nonlinear models only).

    ``inner`` carries the options of the method solving each linearised
    subproblem; ``None`` means the method's defaults.  Passing a bare
    method-options instance to :class:`~repro.core.estimator.Estimator`
    for a nonlinear model is equivalent to
    ``IteratedOptions(inner=that_instance)``.

    ``linearization`` selects how each iteration linearises the model: a
    registered name (``"taylor"``, ``"unscented"``, ``"cubature"``,
    ``"gauss_hermite"``) or a :class:`repro.linearize.Linearization`
    instance.  Resolved to an instance at construction, so a bad name
    fails here, not inside a trace, and the resolved strategy rides the
    frozen options into the executable-cache key.
    """

    iterations: int = 5
    divergence_correction: bool = False
    inner: Optional[SolverOptions] = None
    linearization: object = "taylor"

    def __post_init__(self) -> None:
        if not isinstance(self.iterations, int) or self.iterations < 1:
            raise ValueError(
                f"iterations must be a positive int, got {self.iterations!r}")
        if self.inner is not None and not isinstance(self.inner,
                                                     SolverOptions):
            raise TypeError(
                f"inner must be a SolverOptions instance, got "
                f"{type(self.inner).__name__}")
        # Lazy import: repro.linearize imports jax at module load; options
        # must stay importable without touching the solver stack.
        from repro.linearize import get_linearization

        object.__setattr__(self, "linearization",
                           get_linearization(self.linearization))

    def replace(self, **changes) -> "IteratedOptions":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class SigmaPointOptions(IteratedOptions):
    """Options of the ``sigma_point`` method: the iterated
    posterior-linearisation smoother (sigma-point SLR instead of Taylor).

    ``inner_method`` names the registered LINEAR method each linearised
    subproblem is solved with (``"parallel_rts"``, ``"sequential_rts"``,
    ``"parallel_kernel"``, ``"distributed"``, ...); ``inner`` carries that
    method's options (``None`` = its defaults).  ``linearization``
    defaults to the unscented SLR family; any registered strategy --
    including ``"taylor"``, which makes ``sigma_point`` coincide with the
    plain IEKS -- is accepted.
    """

    linearization: object = "unscented"
    inner_method: str = "parallel_rts"

    def __post_init__(self) -> None:
        super().__post_init__()
        if not isinstance(self.inner_method, str) or not self.inner_method:
            raise ValueError(
                f"inner_method must be a non-empty method name, "
                f"got {self.inner_method!r}")
