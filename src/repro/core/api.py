"""Top-level user API for continuous-time MAP trajectory estimation.

    from repro.core import map_estimate
    sol = map_estimate(model, ts, y, method="parallel_rts")

``model`` is a :class:`~repro.core.sde.LinearSDE` or
:class:`~repro.core.sde.NonlinearSDE`; nonlinear models are solved with the
iterated linearisation of section 4.4.  All solvers are jit-friendly pure
functions; batches of measurement records are handled by
:func:`~repro.core.batching.map_estimate_batched` (stacked records) and
:func:`~repro.core.batching.map_estimate_ragged` (pad-and-bucket for
ragged record lengths).

``measurement_mask`` zeroes the information contribution of selected
measurement intervals (mask 0.0) while keeping the dynamics prior intact;
it is what makes length-padding exact (a padded tail beyond ``t_f`` with
no measurements adds zero Onsager-Machlup cost and leaves the MAP estimate
on the real window unchanged), and it doubles as a missing-data mask.
"""
from __future__ import annotations

from typing import Optional, Union

import jax.numpy as jnp

from .nonlinear import iterated_map
from .registry import get_solver, method_names
from .sde import LinearSDE, NonlinearSDE, grid_lqt_from_linear

# Static snapshot of the BUILT-IN methods (back-compat export).  Methods
# added later via ``registry.register_method`` appear in ``method_names()``
# (the live view), not here.
METHODS = method_names()


def map_estimate(
    model: Union[LinearSDE, NonlinearSDE],
    ts: jnp.ndarray,
    y: jnp.ndarray,
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
    measurement_mask: Optional[jnp.ndarray] = None,
):
    solver = get_solver(method)

    if isinstance(model, NonlinearSDE):
        return iterated_map(
            model, ts, y, iterations=iterations, method=method, nsub=nsub,
            mode=mode, divergence_correction=divergence_correction,
            measurement_mask=measurement_mask)

    grid = grid_lqt_from_linear(model, ts, y,
                                measurement_mask=measurement_mask)
    return solver(grid, nsub, mode)
