"""Serving engine tests: batched generation, continuous batching waves,
greedy consistency with manual decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import transformer
from repro.serving.engine import Request, ServeEngine


def _setup(name="smollm-135m-smoke"):
    cfg = dataclasses.replace(get_config(name), dtype="float32")
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_batched_generation():
    cfg, params = _setup()
    engine = ServeEngine(cfg, params, batch=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=8)
                    .astype(np.int32), max_new_tokens=5)
            for _ in range(6)]   # 6 requests > batch 4 -> two waves
    done = engine.generate(reqs)
    assert len(done) == 6
    for r in done:
        assert r.out.shape == (5,)
        assert (0 <= r.out).all() and (r.out < cfg.vocab_size).all()


def test_engine_matches_manual_greedy_decode():
    cfg, params = _setup()
    engine = ServeEngine(cfg, params, batch=1, max_len=64)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    [req] = engine.generate([Request(prompt=prompt, max_new_tokens=4)])

    # manual: prefill + argmax loop
    logits, caches = transformer.prefill(
        params, {"tokens": jnp.asarray(prompt)[None]}, cfg, max_len=64)
    cur = int(jnp.argmax(logits[0, -1]))
    manual = [cur]
    for _ in range(3):
        lg, caches = transformer.decode_step(
            params, jnp.asarray([cur], jnp.int32), caches, cfg)
        cur = int(jnp.argmax(lg[0]))
        manual.append(cur)
    np.testing.assert_array_equal(req.out, np.asarray(manual, np.int32))


def test_engine_ssm_arch():
    cfg, params = _setup("mamba2-370m-smoke")
    engine = ServeEngine(cfg, params, batch=2, max_len=64)
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=5)
                    .astype(np.int32), max_new_tokens=3)
            for _ in range(2)]
    done = engine.generate(reqs)
    assert all(r.out.shape == (3,) for r in done)
