"""Nonlinear tracking: iterated parallel MAP on the coordinated-turn model.

Reproduces the paper's section 5.2 setup (range-bearing measurements of a
turning target, 5 linearisation iterations) and prints the per-iteration
Onsager-Machlup cost, demonstrating the Gauss-Newton descent of the
continuous-time IEKS with a parallel-in-time inner solver.

    PYTHONPATH=src python examples/coordinated_turn_ieks.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.configs.coordinated_turn import CoordinatedTurnConfig
from repro.core import (
    iterated_map, om_cost_nonlinear, simulate_nonlinear, time_grid,
)

cfg = CoordinatedTurnConfig()
model = cfg.model()
T, n = 128, 10
ts = time_grid(cfg.t0, cfg.tf, T * n)
x_true, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(42))

print("iter | OM cost      | pos RMSE")
prev = None
for it in range(1, cfg.iterations + 1):
    sol = iterated_map(model, ts, y, iterations=it, method="parallel_rts",
                       nsub=n, mode="discrete")
    cost = float(om_cost_nonlinear(model, ts, y, sol.x))
    rmse = float(jnp.sqrt(jnp.mean((sol.x[:, :2] - x_true[:, :2]) ** 2)))
    print(f"  {it}  | {cost:12.2f} | {rmse:.4f}")
    if prev is not None:
        assert cost <= prev * 1.001, "IEKS cost must not increase"
    prev = cost

seq = iterated_map(model, ts, y, iterations=cfg.iterations,
                   method="sequential_rts", mode="discrete")
gap = float(jnp.abs(sol.x - seq.x).max())
print(f"parallel vs sequential IEKS max gap: {gap:.2e}")
assert gap < 1e-6
print("OK")
