"""Mixture-of-experts FFN: top-k routing, capacity dispatch, EP sharding.

Dispatch is sort-based (Megablocks-style ranking, no (T, E) cumsum blow-up):
token-slot assignments are ranked within their expert via a stable argsort;
assignments past the per-expert capacity are dropped (their gate weight is
lost, standard dropping-MoE semantics).  Expert compute is a dense
(E, cap, D) x (E, D, F) einsum so GSPMD can shard the expert dimension over
the model axis (EP) -- or fall back to sharding d_ff when E does not divide
the axis (granite's 40 experts on a 16-way axis; DESIGN.md S5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import logical_constraint

from .layers import P, activation


def moe_spec(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    spec = {
        "router": P((D, E), ("embed", None)),
        "wu": P((E, D, F), ("experts", "embed", "ff"), fan_in=D),
        "wd": P((E, F, D), ("experts", "ff", "embed"), fan_in=F),
    }
    if cfg.mlp_type == "gated":
        spec["wg"] = P((E, D, F), ("experts", "embed", "ff"), fan_in=D)
    return spec


def moe_forward(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D)."""
    Bb, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_topk
    T = Bb * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # capacity: cf-scaled mean load, floored at K, ceiled at the no-drop
    # bound T*K (tiny decode batches must never drop)
    cap = int(max(K, (K * T / E) * cfg.moe_capacity_factor))
    cap = min(cap, T * K)
    # pad capacity to the lane width so the buffers tile cleanly
    cap = (cap + 127) // 128 * 128 if cap > 128 else cap
    cap = min(cap, T * K)

    e_flat = idx.reshape(T * K)
    # rank each assignment within its expert (stable -> earlier tokens win)
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E))        # (E,)
    rank_sorted = jnp.arange(T * K) - start[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap
    slot = jnp.where(keep, rank, cap - 1)

    # scatter tokens into (E, cap, D); the capacity dim shards over the
    # data axes (experts shard over model when E divides, DESIGN.md S5) --
    # without the cap constraint XLA replicates multi-GB dispatch buffers
    x_rep = jnp.repeat(xt[:, None], K, axis=1).reshape(T * K, D)
    buf = jnp.zeros((E, cap, D), xt.dtype)
    buf = buf.at[e_flat, slot].add(
        jnp.where(keep[:, None], x_rep, 0), mode="drop")
    buf = logical_constraint(buf, "experts", "batch", None)

    act = activation(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", buf, params["wu"])
    if cfg.mlp_type == "gated":
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
        hidden = act(g) * up
    else:
        hidden = act(up)
    hidden = logical_constraint(hidden, "experts", "batch", "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, params["wd"])
    out_buf = logical_constraint(out_buf, "experts", "batch", None)

    gathered = out_buf[e_flat, slot]                          # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(T, K, D)
         * gate.astype(gathered.dtype)[..., None]).sum(axis=1)
    y = y.reshape(Bb, S, D)
    return logical_constraint(y, "batch", None, None)


def moe_aux_loss(params, x, cfg: ModelConfig):
    """Load-balancing auxiliary loss (Switch-style)."""
    Bb, S, D = x.shape
    xt = x.reshape(Bb * S, D)
    logits = jnp.einsum("td,de->te", xt, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.moe_topk)
    onehot = jax.nn.one_hot(idx[:, 0], cfg.moe_experts, dtype=jnp.float32)
    frac_tokens = onehot.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    return cfg.moe_experts * jnp.sum(frac_tokens * frac_probs)
