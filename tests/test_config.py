"""Config system + shape-suite + sharding-rule tests."""
import jax

from repro.config import (
    SHAPE_SUITE, get_config, list_configs, shape_skip_reason,
)
from repro.configs import ARCHS
from repro.distributed.sharding import choose_pspec, mesh_context
from repro.models import transformer
from repro.models.layers import params_axes, params_shapes
from repro.models.transformer import model_spec


def test_registry_has_all_archs():
    known = list_configs()
    for a in ARCHS:
        assert a in known and a + "-smoke" in known


def test_shape_suite_cells():
    assert [s.name for s in SHAPE_SUITE] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k"]
    # skip accounting: exactly 8 documented skips (DESIGN.md S4)
    skips = [(a, s.name) for a in ARCHS for s in SHAPE_SUITE
             if shape_skip_reason(get_config(a), s)]
    assert len(skips) == 8, skips
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    for a in ("smollm-135m", "qwen3-4b", "starcoder2-15b",
              "llava-next-34b", "phi3.5-moe-42b-a6.6b",
              "granite-moe-3b-a800m"):
        assert (a, "long_500k") in skips
    # SSM / hybrid / SWA archs RUN long_500k
    for a in ("mamba2-370m", "hymba-1.5b", "h2o-danube-1.8b"):
        assert (a, "long_500k") not in skips


def test_spec_axes_match_param_tree():
    for a in ARCHS:
        cfg = get_config(a + "-smoke")
        spec = model_spec(cfg)
        axes = params_axes(spec)
        shapes = params_shapes(spec)
        params = transformer.init(cfg, jax.random.PRNGKey(0))
        is_ax = lambda x: isinstance(x, tuple)
        ax_leaves, ta = jax.tree_util.tree_flatten(axes, is_leaf=is_ax)
        sh_leaves, _ = jax.tree_util.tree_flatten(shapes, is_leaf=is_ax)
        p_leaves, tp = jax.tree_util.tree_flatten(params)
        assert ta == tp, a
        for ax, shp, p in zip(ax_leaves, sh_leaves, p_leaves):
            assert tuple(shp) == p.shape, (a, ax, shp, p.shape)
            assert len(ax) == p.ndim


def test_choose_pspec_divisibility_fallback():
    # uses the single real device -> build a fake mesh via abstract mesh
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh_context(mesh):
        # with model axis size 1 everything divides; spot-check priorities
        sp = choose_pspec((100, 56, 128), ("embed", "heads", "head"))
        assert sp == P(None, "model", None)
    # llava-like fallback logic is exercised in the dry-run (16-way axis)


def test_param_counts_active_vs_total():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.active_param_count() < phi.param_count() * 0.3
    dense = get_config("qwen3-4b")
    assert dense.active_param_count() == dense.param_count()
