"""repro: parallel-in-time continuous MAP estimation + LM framework.

See DESIGN.md for the system inventory.
"""
__version__ = "0.1.0"
