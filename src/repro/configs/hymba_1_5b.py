"""hymba-1.5b: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 -- parallel attention + mamba heads per layer
[arXiv:2411.13676].

Hybrid mixer: each layer computes attention and SSD on the same input and
averages the per-branch-normalised outputs.  Local layers use SWA (1k
window) making long_500k legal for the attention branch too.
"""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        head_dim=64, d_ff=5504, vocab_size=32001, mixer="hybrid",
        window=1024, ssm_state=16, ssm_head_dim=64, ssm_expand=2,
        ssm_chunk=256, remat_group=8)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="hymba-1.5b-smoke", num_layers=2, d_model=64,
        num_heads=5, num_kv_heads=1, head_dim=16, d_ff=128,
        vocab_size=128, window=32, ssm_state=8, ssm_head_dim=16,
        ssm_chunk=16)
