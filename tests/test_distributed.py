"""Multi-device feature tests (8 forced host devices, subprocess-isolated):
pipeline parallelism, compressed gradient all-reduce, and the sharded
train step (TP+ZeRO-1 NamedShardings) vs the single-device step."""
import os
import subprocess
import sys
import textwrap

import pytest

_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
"""


def _run(snippet: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _COMMON + textwrap.dedent(snippet)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return out.stdout


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    out = _run("""
    from repro.distributed.pipeline import pipeline_forward
    mesh = jax.make_mesh((8,), ("pipe",))
    rng = np.random.default_rng(0)
    S, D, M = 8, 16, 4          # stages, width, microbatches
    Ws = jnp.asarray(rng.standard_normal((S, D, D)) / np.sqrt(D))
    xs = jnp.asarray(rng.standard_normal((M, 3, D)))

    def stage(w, x):
        return jnp.tanh(x @ w)

    got = pipeline_forward(stage, Ws, xs, mesh, axis_name="pipe")

    ref = xs
    for i in range(S):
        ref = jnp.tanh(ref @ Ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-9, atol=1e-9)
    print("PIPELINE-OK")
    """)
    assert "PIPELINE-OK" in out


@pytest.mark.slow
def test_grad_compression_error_feedback():
    out = _run("""
    from repro.distributed.grad_compress import (
        compressed_psum, init_error_state, make_compressed_dp_step)
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)

    # 1) single compressed psum ~ exact psum within bf16 quantisation
    g = {"w": jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)}
    err = init_error_state({"w": g["w"][0]})
    f = shard_map(partial(compressed_psum, axis_name="data"),
                  mesh=mesh, in_specs=({"w": P("data")}, {"w": P()}),
                  out_specs=({"w": P()}, {"w": P()}), check_rep=False)
    mean, new_err = f(g, err)
    exact = g["w"].mean(axis=0)
    q_err = np.abs(np.asarray(mean["w"][0]) - np.asarray(exact)).max()
    assert q_err < 0.05, q_err

    # 2) error feedback: repeated compression of a CONSTANT gradient
    # converges (error is re-injected, not lost)
    tot = jnp.zeros((64,))
    err = init_error_state({"w": g["w"][0]})
    steps = 40
    for _ in range(steps):
        mean, err = f(g, err)
        tot = tot + mean["w"][0]
    drift = np.abs(np.asarray(tot / steps) - np.asarray(exact)).max()
    assert drift < 2e-3, drift
    print("GRADCOMP-OK", q_err, drift)
    """)
    assert "GRADCOMP-OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = _run("""
    import dataclasses
    from repro.config import get_config, TrainConfig
    from repro.distributed.sharding import mesh_context, choose_pspec
    from repro.models import transformer
    from repro.train.optimizer import adamw_init
    from repro.train.trainer import make_shardings, make_train_step
    from jax.sharding import NamedSharding

    cfg = dataclasses.replace(get_config("smollm-135m-smoke"),
                              dtype="float32")
    tcfg = TrainConfig(total_steps=4, warmup_steps=1)
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    B, S = 8, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size),
    }
    step = make_train_step(cfg, tcfg)
    p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh_context(mesh):
        p_sh, o_sh = make_shardings(cfg, tcfg, mesh)
        b_sh = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, choose_pspec(
                x.shape, ("batch",) + (None,) * (x.ndim - 1), mesh)),
            batch)
        sharded = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None))
        params_d = jax.device_put(params, p_sh)
        opt_d = jax.device_put(opt, o_sh)
        batch_d = jax.device_put(batch, b_sh)
        p_got, o_got, m_got = sharded(params_d, opt_d, batch_d)

    np.testing.assert_allclose(float(m_got["loss"]), float(m_ref["loss"]),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(p_got),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)
    print("SHARDED-TRAIN-OK")
    """)
    assert "SHARDED-TRAIN-OK" in out


@pytest.mark.slow
def test_distributed_temporal_map_solver():
    """The paper's solver with its time axis sharded across 8 devices:
    the distributed backward scan == the single-device scan."""
    out = _run("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import (
        lqt_combine, suffix_scan, distributed_scan, grid_lqt_from_linear,
        simulate_linear, time_grid)
    from repro.core.elements import discrete_block_elements, terminal_element
    from repro.core.types import LQTElement
    import sys
    sys.path.insert(0, "tests")

    import jax.numpy as jnp
    F = jnp.block([[jnp.zeros((2, 2)), jnp.eye(2)], [jnp.zeros((2, 4))]])
    H = jnp.concatenate([jnp.eye(2), jnp.zeros((2, 2))], axis=1)
    L = jnp.concatenate([jnp.zeros((2, 2)), jnp.eye(2)], axis=0)
    from repro.core import LinearSDE
    model = LinearSDE(F=F, c=jnp.zeros(4), H=H, r=jnp.zeros(2),
                      Q=L @ (4.0 * jnp.eye(2)) @ L.T,
                      R=1e-2 * jnp.eye(2),
                      m0=jnp.array([5.0, 5.0, 0.0, 0.0]), P0=jnp.eye(4))
    T, n = 64, 5
    ts = time_grid(0.0, 5.0, T * n)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    grid = grid_lqt_from_linear(model, ts, y)
    blocks, _ = discrete_block_elements(grid, n)
    elems = jax.tree_util.tree_map(
        lambda a, t: jnp.concatenate([a, t[None]], axis=0),
        blocks, terminal_element(grid))
    # pad to multiple of 8 with identity elements on the right...
    # simpler: shard 65 -> use 64 blocks + fold terminal into last block
    last = jax.tree_util.tree_map(lambda a: a[-2], elems)
    term = jax.tree_util.tree_map(lambda a: a[-1], elems)
    folded = lqt_combine(last, term)
    elems64 = jax.tree_util.tree_map(
        lambda a, f: jnp.concatenate([a[:-2], f[None]], axis=0),
        elems, folded)

    want = suffix_scan(lqt_combine, elems64)
    mesh = jax.make_mesh((8,), ("t",))
    spec = LQTElement(*(P("t"),) * 5)
    f = shard_map(partial(distributed_scan, lqt_combine, axis_name="t",
                          reverse=True),
                  mesh=mesh, in_specs=(spec,), out_specs=spec)
    got = f(elems64)
    import numpy as np
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-7, atol=1e-8)
    print("DIST-MAP-OK")
    """)
    assert "DIST-MAP-OK" in out
