"""First-order Taylor linearisation (the paper's section 4.4 path).

Extracted verbatim from the old ``NonlinearSDE.linearise`` so the default
iterated smoother is bit-exact with the pre-subsystem code:
``g(x, t) ~= A x + b`` with ``A = jacfwd(g)(xbar)`` and
``b = g(xbar) - A xbar``.  No residual covariance (``Omega`` is ``None``
statically), so the grid builder leaves ``Q``/``R`` untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from .base import Linearization, register_linearization


def taylor_linearize_point(g: Callable, x, t):
    """``(A, b)`` of the first-order expansion of ``g`` about ``x``."""
    A = jax.jacfwd(g, argnums=0)(x, t)
    b = g(x, t) - A @ x
    return A, b


def taylor_linearize_grid(g: Callable, xb, tl):
    """Grid Taylor expansion: vmap of :func:`taylor_linearize_point` over
    the interval left points (``xb`` ``(N, nx)``, ``tl`` ``(N,)``) --
    the exact computation the solvers linearised with before the
    subsystem existed."""
    def lin(x, t):
        return taylor_linearize_point(g, x, t)
    return jax.vmap(lin)(xb, tl)


@dataclasses.dataclass(frozen=True)
class Taylor(Linearization):
    """Jacobian (first-order Taylor) linearisation -- the IEKS default."""

    has_residual = False

    def __call__(self, g: Callable, x, t, cov=None):
        A, b = taylor_linearize_point(g, x, t)
        return A, b, None

    def linearize_grid(self, g: Callable, xb, tl, covs=None):
        A, b = taylor_linearize_grid(g, xb, tl)
        return A, b, None


register_linearization("taylor", Taylor)
