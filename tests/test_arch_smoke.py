"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / decode step on CPU, asserting shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, TrainConfig, get_config
from repro.configs import ARCHS
from repro.models import transformer
from repro.train.optimizer import adamw_init
from repro.train.trainer import make_train_step

B, S = 2, 32


def _batch(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    batch = {"labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["embeddings"] = jax.random.normal(
            k1, (B, S, cfg.d_model), jnp.float32) * 0.1
    else:
        batch["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.fixture(params=ARCHS, ids=list(ARCHS))
def smoke_cfg(request):
    return get_config(request.param + "-smoke")


def test_train_loss_finite(smoke_cfg):
    cfg = smoke_cfg
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss = transformer.train_loss(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), cfg.name
    # a uniform-random model should sit near log(vocab)
    assert float(loss) < np.log(cfg.vocab_size) * 2 + 1.0


def test_train_step_updates(smoke_cfg):
    cfg = smoke_cfg
    tcfg = TrainConfig(total_steps=10, warmup_steps=2, microbatches=2)
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # at least one parameter tensor moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved, cfg.name


def test_decode_step(smoke_cfg):
    cfg = smoke_cfg
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step (DESIGN.md S4)")
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    caches = transformer.init_caches(cfg, B, max_len=64)
    tokens = jnp.zeros((B,), jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, t, c: transformer.decode_step(p, t, c, cfg)
    )(params, tokens, caches)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), cfg.name
    if new_caches.attn is not None:
        assert int(new_caches.attn.pos[0]) == 1


def test_prefill_matches_decode(smoke_cfg):
    """prefill caches + one decode step == forward over the sequence.

    Verified via next-token logits: decode after a T-token prefill must
    match the (T+1)-length teacher-forced forward's last-position logits.
    """
    cfg = smoke_cfg
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step")
    if cfg.input_mode == "embeddings":
        pytest.skip("embedding-input: decode consumes tokens; parity "
                    "checked on token models")
    cfg = dataclasses.replace(cfg, dtype="float32")  # tight comparison
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    T = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T + 1), 0,
                              cfg.vocab_size)
    logits_pre, caches = transformer.prefill(
        params, {"tokens": toks[:, :T]}, cfg, max_len=64)
    logits_dec, _ = transformer.decode_step(params, toks[:, T], caches, cfg)

    # oracle: run prefill on T+1 tokens, read its last-position logits
    logits_full, _ = transformer.prefill(
        params, {"tokens": toks}, cfg, max_len=64)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, 0]),
        rtol=2e-3, atol=2e-3)


def test_full_configs_construct():
    """exact assigned configs instantiate and report sane param counts."""
    expect = {
        "hubert-xlarge": (0.8e9, 1.3e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "llava-next-34b": (30e9, 40e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "qwen3-4b": (3.0e9, 5.0e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "starcoder2-15b": (13e9, 17e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 45e9),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
    }
    for name in ARCHS:
        cfg = get_config(name)
        n = cfg.param_count()
        lo, hi = expect[name]
        assert lo <= n <= hi, (name, n)
        if cfg.is_moe:
            assert cfg.active_param_count() < n


def test_ssm_split_proj_variant():
    """ssm_fused_proj=False (the sharding-clean variant) trains and keeps
    decode/prefill parity within its own parameterisation."""
    import dataclasses
    import jax.numpy as jnp
    from repro.models import transformer as tf

    cfg = dataclasses.replace(get_config("mamba2-370m-smoke"),
                              ssm_fused_proj=False, dtype="float32")
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 17), 0,
                              cfg.vocab_size)
    loss = tf.train_loss(params, {"tokens": toks[:, :-1],
                                  "labels": toks[:, 1:]}, cfg)
    assert bool(jnp.isfinite(loss))

    logits_pre, caches = tf.prefill(params, {"tokens": toks[:, :16]}, cfg,
                                    max_len=64)
    logits_dec, _ = tf.decode_step(params, toks[:, 16], caches, cfg)
    logits_full, _ = tf.prefill(params, {"tokens": toks}, cfg, max_len=64)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, 0]),
        rtol=2e-3, atol=2e-3)
