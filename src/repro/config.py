"""Configuration system: model / training / serving / mesh configs.

Plain frozen dataclasses (hashable -> usable as jit static args), a config
registry populated by ``repro.configs``, and the input-shape suites assigned
to every architecture (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    mlp_type: str = "gated"      # gated | plain | none
    act: str = "silu"            # silu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None             # sliding-window attention
    causal: bool = True
    input_mode: str = "tokens"               # tokens | embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # layer mixer: attn | ssm | hybrid (parallel attn+ssm heads)
    mixer: str = "attn"

    # SSM (mamba2/SSD) parameters
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity_factor: float = 1.25

    dtype: str = "bfloat16"
    remat: bool = True
    # two-level (sqrt) remat: scan over groups of this many layers with a
    # checkpoint around each group AND each layer -- carry storage drops
    # from L to L/g + g at one extra in-group forward (0 = flat remat)
    remat_group: int = 0
    unroll_layers: bool = False   # loop-free lowering (cost-model validation)

    # ---- performance policy knobs (see EXPERIMENTS.md SPerf) ----
    # "tp": weights model-sharded (megatron TP).  "dp_only": weights
    # replicated (vocab still sharded), batch over every mesh axis --
    # right for models too small to amortise TP collectives.
    parallel_policy: str = "tp"
    # megatron-style sequence parallelism: residual stream sharded over
    # the model axis between blocks (AR -> RS+AG on the TP boundaries)
    seq_parallel: bool = False
    # fused in_proj emits one model-sharded tensor that must be split at
    # non-shard-aligned offsets (halo collective-permutes); False uses
    # per-stream projections/convs with clean shardings
    ssm_fused_proj: bool = True
    # when kv_heads < TP degree, replicate the (tiny) KV projections
    # instead of sharding head_dim -- kills the f32 KV all-gathers in the
    # attention backward (megatron GQA practice)
    kv_replicate: bool = False

    # embedding tables are physically padded to this multiple so the vocab
    # dim always divides the TP axis (odd vocabs like hymba's 32001 would
    # otherwise force D-sharded embeddings -- bad layouts AND an XLA SPMD
    # verifier bug under the microbatch scan); pad logits are masked to
    # -inf in the loss/decode heads.
    vocab_pad_multiple: int = 128

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        D, F, L = self.d_model, self.d_ff, self.num_layers
        n = self.padded_vocab * D * (1 if self.tie_embeddings else 2)
        per = 0
        if self.mixer in ("attn", "hybrid"):
            per += D * self.num_heads * self.hd * 2        # q, o
            per += D * self.num_kv_heads * self.hd * 2     # k, v
        if self.mixer in ("ssm", "hybrid"):
            gs = 2 * self.ssm_groups * self.ssm_state
            per += D * (2 * self.ssm_inner + gs + self.ssm_heads)
            per += self.ssm_inner * D
            per += (self.ssm_inner + gs) * self.ssm_conv
        if self.is_moe:
            per += D * self.moe_experts
            mults = 3 if self.mlp_type == "gated" else 2
            per += self.moe_experts * mults * D * F
        elif self.mlp_type != "none":
            mults = 3 if self.mlp_type == "gated" else 2
            per += mults * D * F
        per += 2 * D                                       # norms
        return n + L * per

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        mults = 3 if self.mlp_type == "gated" else 2
        dense_like = self.param_count() - (
            L * self.moe_experts * mults * D * F)
        return dense_like + L * self.moe_topk * mults * D * F


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One dry-run cell: what to lower and at which shape."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPE_SUITE: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)


def shape_skip_reason(model: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """DESIGN.md S4 skip rules; None means the cell must lower+compile."""
    if model.is_encoder and shape.kind == "decode":
        return "encoder-only architecture has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = model.mixer in ("ssm", "hybrid") or model.window
        if not sub_quadratic:
            return ("pure full-attention architecture: 512k decode needs "
                    "sub-quadratic attention (see DESIGN.md S4)")
    return None


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seq_len: int = 1024
    global_batch: int = 8
    microbatches: int = 1        # grad-accumulation steps
    zero1: bool = True           # shard optimizer state over data axis
    grad_compress: bool = False  # bf16 all-reduce with error feedback
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pod: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pod


_REGISTRY: dict = {}


def register_config(name: str, fn) -> None:
    _REGISTRY[name] = fn


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown config {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> list:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
