"""Paper Fig. 1: runtime of sequential vs parallel continuous-time MAP
(Wiener velocity model, eqs. 52-54) as a function of the number of blocks T.

Methods (paper section 5.1): sequential RTS, sequential two-filter,
parallel RTS, parallel two-filter; T blocks x n=10 Euler substeps; mean
runtime over 5 measured iterations after a warmup call.

NOTE on this container: one CPU core executes the associative scan
sequentially, so wall-clock parity (not speedup) is expected here; the
span column reports the algorithmic depth (sequential combines on the
critical path) which is what the GPU/TPU wall-clock follows (paper Fig. 1:
log T vs linear T).  The same harness run on an accelerator reproduces the
paper's separation directly.
"""
from __future__ import annotations

import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp


def run(T_list=(128, 256, 512, 1024, 2048), nsub=10, mode="euler",
        repeats=5, p0=1e-2):
    from repro.configs.wiener_velocity import WienerVelocityConfig
    from repro.core import (
        grid_lqt_from_linear, parallel_rts, parallel_two_filter,
        sequential_rts, sequential_two_filter, simulate_linear, time_grid,
    )

    wcfg = WienerVelocityConfig(p0=p0)
    model = wcfg.model()
    rows = []
    for T in T_list:
        N = T * nsub
        ts = time_grid(wcfg.t0, wcfg.tf, N, dtype=jnp.float32)
        _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
        grid = grid_lqt_from_linear(model, ts, y)

        methods = {
            "seq_rts": jax.jit(lambda g: sequential_rts(g, mode).x),
            "seq_tf": jax.jit(lambda g: sequential_two_filter(g, mode).x),
            "par_rts": jax.jit(
                lambda g: parallel_rts(g, nsub, mode).x),
            "par_tf": jax.jit(
                lambda g: parallel_two_filter(g, nsub, mode).x),
        }
        spans = {
            "seq_rts": 2 * N, "seq_tf": 2 * N,
            "par_rts": 4 * math.ceil(math.log2(T + 1)) + 2 * nsub,
            "par_tf": 4 * math.ceil(math.log2(T + 1)) + 2 * nsub,
        }
        for name, fn in methods.items():
            out = fn(grid)
            out.block_until_ready()        # compile + warmup
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn(grid).block_until_ready()
            dt = (time.perf_counter() - t0) / repeats
            rows.append({
                "name": f"fig1/{name}/T{T}",
                "us_per_call": dt * 1e6,
                "derived": f"span={spans[name]}",
            })
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
