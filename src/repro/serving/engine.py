"""Batched serving engine: prefill + decode with continuous batching lite.

``ServeEngine`` owns jitted prefill/decode step functions and per-request
state.  Requests are padded to a fixed batch (static shapes -> one compiled
executable); finished rows are recycled for the next queued request
(continuous batching without shape churn).  Cache layout and sharding come
from the same logical rules as training (batch over data, heads over
model), so the engine runs unmodified from 1 CPU device to the production
mesh.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch: int = 4,
                 max_len: int = 512, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.greedy = greedy

        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_len=max_len))
        self._decode = jax.jit(
            lambda p, t, c: transformer.decode_step(p, t, c, cfg))

    def _sample(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests with fixed-batch continuous batching."""
        queue = list(requests)
        done: List[Request] = []
        while queue:
            wave = queue[:self.batch]
            queue = queue[self.batch:]
            prompts = [r.prompt for r in wave]
            T = max(len(p) for p in prompts)
            toks = np.zeros((self.batch, T), np.int32)
            for i, p in enumerate(prompts):
                toks[i, T - len(p):] = p   # left-pad to align last token
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)})
            cur = self._sample(logits[:, -1])
            steps = max(r.max_new_tokens for r in wave)
            outs = [[] for _ in wave]
            for i, r in enumerate(wave):
                outs[i].append(cur[i])
            for _ in range(steps - 1):
                logits, caches = self._decode(
                    self.params, jnp.asarray(cur), caches)
                cur = self._sample(logits)
                for i, r in enumerate(wave):
                    if len(outs[i]) < r.max_new_tokens:
                        outs[i].append(cur[i])
            for i, r in enumerate(wave):
                r.out = np.asarray(outs[i], np.int32)
                done.append(r)
        return done
