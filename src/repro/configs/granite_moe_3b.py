"""granite-moe-3b-a800m: 32L d_model=1536 24H (GQA kv=8) expert d_ff=512
vocab=49155, 40 experts top-8 [hf:ibm-granite granite-3.0 MoE family].

40 experts do not divide the 16-way model axis: the EP rule falls back to
sharding the (tiny) expert d_ff -- see DESIGN.md S5 and the roofline notes.
"""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49155,
        moe_experts=40, moe_topk=8, tie_embeddings=True, remat_group=8)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="granite-moe-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32,
        vocab_size=128, moe_experts=5, moe_topk=2,
        moe_capacity_factor=64.0)
