"""GPipe-style pipeline parallelism over a "pipe" mesh axis.

Layers are stacked per stage ((stages, layers_per_stage, ...) weights,
stage dim sharded over the pipe axis); microbatches stream through the
stages with ``collective_permute`` handoffs.  The schedule runs
M + S - 1 ticks for M microbatches over S stages (the classic GPipe
bubble); each tick every stage computes one microbatch and passes its
activation to the next stage.

This is the PP feature module (DESIGN.md S5): the 40-cell dry-run uses
data x model only, but the module is wired for production use and
verified against the sequential stack on an 8-device host mesh
(tests/test_distributed.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn: Callable, stage_params, x_micro,
                     mesh, axis_name: str = "pipe"):
    """Run microbatches through pipeline stages.

    stage_fn(params_stage, x) -> x            (one stage's computation)
    stage_params: leaves with leading dim = n_stages (sharded over pipe)
    x_micro: (M, ...) microbatched input (replicated; stage 0 consumes)
    Returns (M, ...) outputs (replicated from the last stage).
    """
    n_stages = mesh.shape[axis_name]
    M = x_micro.shape[0]
    ticks = M + n_stages - 1

    def body(params_stage, xm):
        # params_stage: (1, ...) local stage slice; xm: full (M, ...)
        params_local = jax.tree_util.tree_map(
            lambda a: a[0], params_stage)
        stage = jax.lax.axis_index(axis_name)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        buf = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)

        def tick(t, carry):
            buf, outs = carry
            mb_in = t                      # microbatch entering stage 0
            feed = jnp.where(mb_in < M, mb_in, M - 1)
            x0 = jax.lax.dynamic_index_in_dim(xm, feed, 0, keepdims=False)
            inp = jnp.where(stage == 0, x0, buf)
            # stage s works on microbatch t - s when 0 <= t - s < M
            active = jnp.logical_and(t - stage >= 0, t - stage < M)
            y = stage_fn(params_local, inp)
            y = jnp.where(active, y, buf)
            # deliver finished microbatches from the last stage
            done_mb = t - (n_stages - 1)
            outs = jax.lax.cond(
                jnp.logical_and(done_mb >= 0, stage == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(done_mb, 0), 0),
                lambda o: o, outs)
            # hand activations forward
            buf_next = jax.lax.ppermute(y, axis_name, fwd_perm)
            return (buf_next, outs)

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # broadcast results from the last stage to every shard
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis_name)
        return outs

    spec_params = jax.tree_util.tree_map(lambda _: P(axis_name),
                                         stage_params)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_rep=False,
    )(stage_params, x_micro)
