"""Distributed-scan scaling: ``method="distributed"`` vs single-device.

Forces host-platform devices (CPU) and times the SAME MAP problem solved
through the public Estimator surface at increasing time-shard counts P:

* **strong scaling** -- total block count T fixed, P grows: per-solve
  wall time should fall toward ``O(T/P + P)`` span (on forced HOST
  devices all shards share the physical cores, so the numbers measure
  harness overhead, not real speedup -- the shape of the curve and the
  schema of the rows are what CI gates);
* **weak scaling** -- blocks per shard fixed, T = P * blocks: per-solve
  wall time should stay flat.

``P = 1`` rows run the single-device ``parallel_rts`` scan via the
distributed method's fallback, so each sweep carries its own baseline.

    PYTHONPATH=src python benchmarks/distributed_scaling.py [--smoke] \\
        [--json PATH] [--emit-rows]

``--emit-rows`` prints one JSON object per row (for ``benchmarks/run.py``,
which runs this script as a subprocess: the parent's jax is already
initialised with the real device count, and XLA's forced host-device
count locks at first init).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

DEVICES = int(os.environ.get("REPRO_BENCH_DEVICES", "8"))
# must precede the first jax import: the device count locks at init
os.environ.setdefault("XLA_FLAGS",
                      f"--xla_force_host_platform_device_count={DEVICES}")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp


def _time_solve(est, problem, ts, ys, repeats: int) -> float:
    compiled = est.lower(problem).compile()          # AOT: no retrace
    compiled(ts, ys).x.block_until_ready()           # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        compiled(ts, ys).x.block_until_ready()
    return (time.perf_counter() - t0) / repeats


def run(strong_T=512, weak_blocks=128, nsub=10, repeats=3, smoke=False):
    from repro.configs.wiener_velocity import WienerVelocityConfig
    from repro.core import DistributedOptions, Estimator, Problem
    from repro.core import simulate_linear, time_grid

    if smoke:
        strong_T, weak_blocks, nsub, repeats = 32, 16, 5, 1

    shard_counts = [p for p in (1, 2, 4, 8) if p <= jax.device_count()]
    wcfg = WienerVelocityConfig(p0=1.0)
    model = wcfg.model()

    def solve_time(T: int, P: int) -> float:
        ts = time_grid(wcfg.t0, wcfg.tf, T * nsub, dtype=jnp.float32)
        _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
        est = Estimator(model, method="distributed",
                        options=DistributedOptions(
                            nsub=nsub, mode="discrete",
                            devices_per_time=P))
        return _time_solve(est, Problem.single(model, ts, y), ts, y,
                           repeats)

    rows = []
    base = None
    for P in shard_counts:                            # strong: T fixed
        dt = solve_time(strong_T, P)
        base = dt if P == 1 else base
        rows.append({
            "name": f"dist/strong/P{P}_T{strong_T}",
            "us_per_call": dt * 1e6,
            "derived": f"speedup_vs_p1={base / dt:.2f}",
        })
    base = None
    for P in shard_counts:                            # weak: T/P fixed
        dt = solve_time(weak_blocks * P, P)
        base = dt if P == 1 else base
        rows.append({
            "name": f"dist/weak/P{P}_T{weak_blocks * P}",
            "us_per_call": dt * 1e6,
            "derived": f"efficiency_vs_p1={base / dt:.2f}",
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI bit-rot check)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write a BENCH json artifact for this section")
    ap.add_argument("--emit-rows", action="store_true",
                    help="print one JSON row per line (run.py subprocess)")
    args = ap.parse_args()
    import repro.obs as obs
    if args.json:
        obs.enable()
        obs.reset()
    rows = run(smoke=args.smoke)
    if args.emit_rows:
        for r in rows:
            print(json.dumps(r))
    else:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        obs.write_bench_json(
            args.json, obs.bench_record("dist", rows, seeds={"dist": 0}))


if __name__ == "__main__":
    main()
