"""The legacy function surface is deprecation shims over Estimator/Problem.

This is the ONLY test module allowed to touch the old entry points: tier-1
runs with ``DeprecationWarning`` promoted to an error (see pyproject), so
any internal code still calling the old surface fails loudly.  Every shim
must (a) warn and (b) return results numerically identical to the new API
-- they construct the same Problem/Estimator and hit the same cached
executable, so the comparison is exact (``assert_array_equal``), for both
linear and nonlinear (coordinated-turn) models and both sequential and
parallel methods.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import coordinated_turn, wiener_velocity
from repro.core import (
    Estimator,
    ParallelOptions,
    Problem,
    SequentialOptions,
    get_method,
    get_solver,
    grid_lqt_from_linear,
    iterated_map,
    legacy_options,
    map_estimate,
    map_estimate_batched,
    map_estimate_ragged,
    method_names,
    register_method,
    sequential_rts,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)
from repro.serving import TrajectoryEngine

NSUB = 5
METHODS_UNDER_TEST = ["sequential_rts", "parallel_rts"]


@pytest.fixture(scope="module")
def linear_problem():
    model = wiener_velocity()
    ts = time_grid(0.0, 1.0, 4 * NSUB)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    return model, ts, y


@pytest.fixture(scope="module")
def nonlinear_problem():
    model = coordinated_turn()
    ts = time_grid(0.0, 1.0, 4 * NSUB)
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(1))
    return model, ts, y


def _assert_same(old, new, fields=("x", "S", "v")):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(old, f)), np.asarray(getattr(new, f)),
            err_msg=f"shim diverged from Estimator surface on {f!r}")


@pytest.mark.parametrize("method", METHODS_UNDER_TEST)
def test_map_estimate_linear_equivalence(linear_problem, method):
    model, ts, y = linear_problem
    with pytest.warns(DeprecationWarning, match="map_estimate"):
        old = map_estimate(model, ts, y, method=method, nsub=NSUB,
                           mode="discrete")
    new = Estimator(
        model, method=method,
        options=get_method(method).options_cls.from_legacy(
            nsub=NSUB, mode="discrete"),
    ).solve(Problem.single(model, ts, y))
    _assert_same(old, new)


@pytest.mark.parametrize("method", METHODS_UNDER_TEST)
def test_map_estimate_nonlinear_equivalence(nonlinear_problem, method):
    model, ts, y = nonlinear_problem
    with pytest.warns(DeprecationWarning, match="map_estimate"):
        old = map_estimate(model, ts, y, method=method, nsub=NSUB,
                           mode="euler", iterations=3)
    new = Estimator(
        model, method=method,
        options=legacy_options(model, method, nsub=NSUB, mode="euler",
                               iterations=3),
    ).solve(Problem.single(model, ts, y))
    _assert_same(old, new)
    np.testing.assert_array_equal(np.asarray(old.cost_trace),
                                  np.asarray(new.cost_trace))


@pytest.mark.parametrize("method", METHODS_UNDER_TEST)
def test_iterated_map_equivalence(nonlinear_problem, method):
    model, ts, y = nonlinear_problem
    with pytest.warns(DeprecationWarning, match="iterated_map"):
        old = iterated_map(model, ts, y, iterations=3, method=method,
                           nsub=NSUB, mode="discrete", x_init=model.m0)
    new = Estimator(
        model, method=method,
        options=legacy_options(model, method, nsub=NSUB, mode="discrete",
                               iterations=3),
    ).solve(Problem.single(model, ts, y, x_init=model.m0))
    _assert_same(old, new)


def test_map_estimate_batched_equivalence(linear_problem):
    model, ts, y = linear_problem
    ys = jnp.stack([y, y * 0.5])
    with pytest.warns(DeprecationWarning, match="map_estimate_batched"):
        old = map_estimate_batched(model, ts, ys, method="parallel_rts",
                                   nsub=NSUB, mode="discrete")
    new = Estimator(
        model, method="parallel_rts",
        options=ParallelOptions(nsub=NSUB, mode="discrete"),
    ).solve(Problem.stacked(model, ts, ys))
    _assert_same(old, new)


def test_map_estimate_ragged_equivalence():
    model = wiener_velocity()
    records = []
    for i, N in enumerate([12, 20, 35]):
        ts_i = time_grid(0.0, N / 20.0, N)
        _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(30 + i))
        records.append((np.asarray(ts_i), np.asarray(y_i)))
    with pytest.warns(DeprecationWarning, match="map_estimate_ragged"):
        old = map_estimate_ragged(model, records, method="parallel_rts",
                                  nsub=NSUB, mode="discrete")
    new = Estimator(
        model, method="parallel_rts",
        options=ParallelOptions(nsub=NSUB, mode="discrete"),
    ).solve(Problem.ragged(model, records))
    assert len(old) == len(new)
    for o, n in zip(old, new):
        _assert_same(o, n)
    assert old[0].padding == new[0].padding


def test_trajectory_engine_legacy_kwargs():
    model = wiener_velocity()
    recs = []
    for i, N in enumerate([12, 20]):
        ts_i = time_grid(0.0, N / 20.0, N)
        _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(40 + i))
        recs.append((np.asarray(ts_i), np.asarray(y_i)))
    with pytest.warns(DeprecationWarning, match="TrajectoryEngine"):
        legacy = TrajectoryEngine(model, batch=2, method="parallel_rts",
                                  nsub=NSUB, mode="discrete")
    modern = TrajectoryEngine(model, batch=2, method="parallel_rts",
                              options=ParallelOptions(nsub=NSUB,
                                                      mode="discrete"))
    assert legacy.estimator.options == modern.estimator.options
    for o, n in zip(legacy.estimate(recs), modern.estimate(recs)):
        _assert_same(o, n)


def test_methods_snapshot_is_now_a_live_view():
    import repro.core as core
    import repro.core.api as api
    register_method("_late_registered",
                    lambda g, o: sequential_rts(g, o.mode),
                    SequentialOptions, overwrite=True)
    for module in (core, api):
        with pytest.warns(DeprecationWarning, match="METHODS"):
            live = module.METHODS
        # the old import-time snapshot silently missed late registrations
        assert "_late_registered" in live
    assert "_late_registered" in method_names()
    with pytest.raises(AttributeError):
        core.NO_SUCH_ATTRIBUTE


def test_get_solver_and_legacy_registration(linear_problem):
    """The pre-options registry surface keeps working: get_solver returns a
    (grid, nsub, mode) adapter, and register_method still accepts a legacy
    (grid, nsub, mode) solver when options_cls is omitted."""
    model, ts, y = linear_problem
    grid = grid_lqt_from_linear(model, ts, y)
    sol = get_solver("sequential_rts")(grid, NSUB, "discrete")
    ref = sequential_rts(grid, "discrete")
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(ref.x))

    register_method("_legacy_sig",
                    lambda g, nsub, mode: sequential_rts(g, mode),
                    overwrite=True)
    spec = get_method("_legacy_sig")
    assert spec.options_cls is ParallelOptions    # legacy default
    out = Estimator(model, method="_legacy_sig",
                    options=ParallelOptions(mode="discrete")
                    ).solve(Problem.single(model, ts, y))
    np.testing.assert_array_equal(np.asarray(out.x), np.asarray(ref.x))


def test_slice_solution_supports_legacy_map_solution():
    from repro.core import MAPSolution, slice_solution
    sol = MAPSolution(x=jnp.zeros((2, 8, 3)), S=jnp.zeros((2, 8, 3, 3)),
                      v=jnp.zeros((2, 8, 3)))
    out = slice_solution(sol, 0, 5)
    assert isinstance(out, MAPSolution)
    assert out.x.shape == (6, 3) and out.cov is None
