"""Per-block scan-element construction and within-block fills.

Two element modes are provided (DESIGN.md S1):

* ``euler`` (paper-faithful): integrate the backward conditional HJB ODEs of
  eq. (43) with explicit Euler over the n substeps of each block (blocks are
  independent -> vmap).  Matches the paper's experimental setup exactly.
* ``discrete`` (beyond-paper numerical upgrade): each Euler substep of the
  control problem admits a CLOSED-FORM conditional value function

      A = I + dt F~,  b = dt c~,  C = dt Q~,
      J = dt H~^T R~^{-1} H~,     eta = dt (H~^T R~^{-1} (y~ - r~) - lin)

  (one Euler step of (43) from the identity boundary, exactly); composing
  these with the exact combine (42) solves the Euler-discretised problem
  EXACTLY, so parallel == sequential to float round-off instead of O(dt).

Also provides the within-block interior fills: backward value fill
(eq. 15 / information-form steps) and forward-value fill (eq. 51).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .combine import apply_element_to_value, lqt_combine
from .types import GridLQT, LQTElement, ValueFn


def _block_view(grid: GridLQT, nsub: int) -> GridLQT:
    """Reshape the substep axis N -> (T, n).  N must be divisible by n."""
    N = grid.N
    assert N % nsub == 0, f"N={N} not divisible by nsub={nsub}"
    T = N // nsub

    def rs(a):
        return None if a is None else a.reshape((T, nsub) + a.shape[1:])

    return GridLQT(
        dt=rs(grid.dt), F=rs(grid.F), c=rs(grid.c), H=rs(grid.H),
        r=rs(grid.r), Q=rs(grid.Q), Rinv=rs(grid.Rinv), y=rs(grid.y),
        S_T=grid.S_T, v_T=grid.v_T, lin=rs(grid.lin),
    )


def _lin_term(grid: GridLQT) -> jnp.ndarray:
    if grid.lin is None:
        return jnp.zeros(grid.c.shape, dtype=grid.c.dtype)
    return grid.lin


def one_step_elements(grid: GridLQT) -> LQTElement:
    """Closed-form single-substep elements (N, ...) -- ``discrete`` mode."""
    dt = grid.dt[:, None, None]
    I = jnp.eye(grid.nx, dtype=grid.F.dtype)
    HtRi = jnp.einsum("kji,kjl->kil", grid.H, grid.Rinv)
    A = I + dt * grid.F
    b = grid.dt[:, None] * grid.c
    C = dt * grid.Q
    J = dt * (HtRi @ grid.H)
    eta = grid.dt[:, None] * (
        jnp.einsum("kij,kj->ki", HtRi, grid.y - grid.r) - _lin_term(grid))
    return LQTElement(A, b, C, eta, J)


def terminal_element(grid: GridLQT) -> LQTElement:
    """The prior element ``a_T`` (section 3.4); A = 0 makes its C inert."""
    Z = jnp.zeros((grid.nx, grid.nx), dtype=grid.F.dtype)
    z = jnp.zeros((grid.nx,), dtype=grid.F.dtype)
    return LQTElement(Z, z, Z, grid.v_T, grid.S_T)


def _hjb_derivs(e: LQTElement, F, c, H, r, Q, Rinv, y, lin):
    """Right-hand sides of eq. (43) (with the optional linear-cost term)."""
    A, b, C, eta, J = e
    HtRi = H.T @ Rinv
    innov = HtRi @ (y - r)
    dA = -A @ (Q @ J + F)
    db = -A @ (Q @ eta + c)
    dC = -A @ Q @ A.T
    deta = J @ (Q @ eta + c) - F.T @ eta - innov + lin
    dJ = J @ Q @ J - J @ F - F.T @ J - HtRi @ H
    return LQTElement(dA, db, dC, deta, dJ)


def _ode_step_backward(deriv_fn, y, dtk, integrator: str):
    """One backward step y(s - dt) of an autonomous-per-interval ODE.

    ``rk4`` treats the interval's coefficients as frozen (they are grid
    samples) but integrates the state nonlinearity (the Riccati quadratic
    terms) to 4th order -- a beyond-paper accuracy/stiffness upgrade over
    the paper's explicit Euler; exact coefficient handling for LTI models.
    """
    tm = jax.tree_util.tree_map
    if integrator == "euler":
        k1 = deriv_fn(y)
        return tm(lambda a, d: a - dtk * d, y, k1)
    if integrator == "rk4":
        h = -dtk
        k1 = deriv_fn(y)
        k2 = deriv_fn(tm(lambda a, d: a + 0.5 * h * d, y, k1))
        k3 = deriv_fn(tm(lambda a, d: a + 0.5 * h * d, y, k2))
        k4 = deriv_fn(tm(lambda a, d: a + h * d, y, k3))
        return tm(
            lambda a, d1, d2, d3, d4: a + (h / 6.0) * (
                d1 + 2 * d2 + 2 * d3 + d4),
            y, k1, k2, k3, k4)
    raise ValueError(f"unknown integrator: {integrator}")


def euler_block_elements(grid: GridLQT, nsub: int,
                         integrator: str = "euler") -> LQTElement:
    """Paper mode: per-block ODE integration of (43), vmapped over blocks.

    Within block ``i`` the integration runs BACKWARD from the identity
    boundary at the block end (eq. 34 boundary conditions A=I, b=0, C=0,
    eta=0, J=0), using the substep-j coefficients for step [tau_j, tau_j+1].
    ``integrator``: "euler" (paper) or "rk4" (beyond-paper, see
    ``_ode_step_backward``).
    """
    g = _block_view(grid, nsub)
    lin = _lin_term(grid).reshape(g.c.shape)

    def block(dt, F, c, H, r, Q, Rinv, y, linb):
        nx = F.shape[-1]
        e0 = LQTElement(
            jnp.eye(nx, dtype=F.dtype), jnp.zeros((nx,), F.dtype),
            jnp.zeros((nx, nx), F.dtype), jnp.zeros((nx,), F.dtype),
            jnp.zeros((nx, nx), F.dtype))

        def step(e, inp):
            dtk, Fk, ck, Hk, rk, Qk, Rik, yk, lk = inp
            nxt = _ode_step_backward(
                lambda ee: _hjb_derivs(ee, Fk, ck, Hk, rk, Qk, Rik, yk,
                                       lk),
                e, dtk, integrator)
            return nxt, None

        out, _ = jax.lax.scan(
            step, e0, (dt, F, c, H, r, Q, Rinv, y, linb), reverse=True)
        return out

    return jax.vmap(block)(g.dt, g.F, g.c, g.H, g.r, g.Q, g.Rinv, g.y, lin)


def discrete_block_elements(
    grid: GridLQT, nsub: int
) -> Tuple[LQTElement, LQTElement]:
    """Exact composition mode: block elements by in-block combine scan.

    Returns ``(block_elems (T,...), substep_elems (T, n, ...))``.
    """
    ones = one_step_elements(grid)
    T = grid.N // nsub
    sub = jax.tree_util.tree_map(
        lambda a: a.reshape((T, nsub) + a.shape[1:]), ones)

    def block(es):
        first = jax.tree_util.tree_map(lambda a: a[0], es)
        rest = jax.tree_util.tree_map(lambda a: a[1:], es)

        def step(carry, e):
            return lqt_combine(carry, e), None

        out, _ = jax.lax.scan(step, first, rest)
        return out

    return jax.vmap(block)(sub), sub


# ---------------------------------------------------------------------------
# Within-block interior fills
# ---------------------------------------------------------------------------

def backward_value_fill_euler(grid: GridLQT, nsub: int, boundary: ValueFn,
                              integrator: str = "euler") -> ValueFn:
    """ODE-integrate the Riccati eqs. (15) backwards inside each block.

    ``boundary`` holds (S, v) at the RIGHT end of each block, i.e. shapes
    (T, nx, nx) / (T, nx).  Returns per-substep values at the LEFT points of
    every substep: shapes (T, n, ...).  ``integrator``: euler (paper) / rk4.
    """
    g = _block_view(grid, nsub)
    lin = _lin_term(grid).reshape(g.c.shape)

    def block(dt, F, c, H, r, Q, Rinv, y, linb, S1, v1):
        def step(carry, inp):
            dtk, Fk, ck, Hk, rk, Qk, Rik, yk, lk = inp
            HtRi = Hk.T @ Rik

            def derivs(sv):
                S, v = sv
                dS = S @ Qk @ S - S @ Fk - Fk.T @ S - HtRi @ Hk
                dv = S @ (Qk @ v + ck) - Fk.T @ v - HtRi @ (yk - rk) + lk
                return (dS, dv)

            Sn, vn = _ode_step_backward(derivs, carry, dtk, integrator)
            Sn = 0.5 * (Sn + Sn.T)
            return (Sn, vn), (Sn, vn)

        _, (Ss, vs) = jax.lax.scan(
            step, (S1, v1), (dt, F, c, H, r, Q, Rinv, y, linb), reverse=True)
        return ValueFn(Ss, vs)

    return jax.vmap(block)(g.dt, g.F, g.c, g.H, g.r, g.Q, g.Rinv, g.y, lin,
                           boundary.S, boundary.v)


def backward_value_fill_discrete(sub_elems: LQTElement, boundary: ValueFn) -> ValueFn:
    """Exact information-form steps inside each block (``discrete`` mode)."""

    def block(es, S1, v1):
        def step(carry, e):
            nxt = apply_element_to_value(e, carry)
            return nxt, nxt

        _, out = jax.lax.scan(step, ValueFn(S1, v1), es, reverse=True)
        return out

    return jax.vmap(block)(sub_elems, boundary.S, boundary.v)


def forward_value_fill_euler(
    grid: GridLQT, nsub: int, left: LQTElement
) -> LQTElement:
    """Euler-integrate the forward HJB ODEs (51) inside each block.

    ``left`` holds the forward conditional value function parameters at the
    LEFT end of each block (shapes (T, ...)); returns parameters at the
    RIGHT point of every substep (shapes (T, n, ...)).  All five equations
    of (51) are propagated: for the usual A = 0 (min-initial-folded) left
    element the (eta, J) equations are identically zero, recovering the
    paper's remark that only the first three are needed; a full-rank left
    element (identity, for block-0 interiors via eq. 39) needs all five.
    """
    g = _block_view(grid, nsub)
    lin = _lin_term(grid).reshape(g.c.shape)

    def block(dt, F, c, H, r, Q, Rinv, y, linb, e0):
        def step(carry, inp):
            A, b, C, eta, J = carry
            dtk, Fk, ck, Hk, rk, Qk, Rik, yk, lk = inp
            HtRi = Hk.T @ Rik
            CHtRi = C @ HtRi
            innov = HtRi @ (yk - rk)
            dA = -CHtRi @ (Hk @ A) + Fk @ A
            db = (C @ innov + Fk @ b + ck
                  - CHtRi @ (Hk @ b) - C @ lk)
            dC = -CHtRi @ (Hk @ C) + Qk + Fk @ C + C @ Fk.T
            deta = A.T @ (innov - HtRi @ (Hk @ b) - lk)
            dJ = A.T @ HtRi @ (Hk @ A)
            An = A + dtk * dA
            bn = b + dtk * db
            Cn = 0.5 * ((C + dtk * dC) + (C + dtk * dC).T)
            en = eta + dtk * deta
            Jn = 0.5 * ((J + dtk * dJ) + (J + dtk * dJ).T)
            nxt = LQTElement(An, bn, Cn, en, Jn)
            return nxt, nxt

        _, out = jax.lax.scan(
            step, e0, (dt, F, c, H, r, Q, Rinv, y, linb))
        return out

    return jax.vmap(block)(g.dt, g.F, g.c, g.H, g.r, g.Q, g.Rinv, g.y, lin,
                           left)


def identity_element(nx: int, dtype) -> LQTElement:
    """V(phi, tau; z, tau): the zero-length-interval identity (eq. 34)."""
    I = jnp.eye(nx, dtype=dtype)
    Z = jnp.zeros((nx, nx), dtype=dtype)
    z = jnp.zeros((nx,), dtype=dtype)
    return LQTElement(I, z, Z, z, Z)


def forward_value_fill_discrete(
    sub_elems: LQTElement, left: LQTElement
) -> LQTElement:
    """Exact in-block forward combine (``discrete`` mode)."""

    def block(es, e0):
        def step(carry, e):
            nxt = lqt_combine(carry, e)
            return nxt, nxt

        _, out = jax.lax.scan(step, e0, es)
        return out

    return jax.vmap(block)(sub_elems, left)
