from .ops import lqt_combine_batched, scan_combine_fn
from .ref import lqt_combine_ref
