from .ops import attention, attention_trainable
from .ref import mha_ref
