"""Accuracy parity: parallel vs sequential vs dense-QP oracle.

This is the paper's core correctness claim ("maintaining the accuracy of
sequential algorithms", section 5): every parallel method must agree with
its sequential counterpart, and the ``discrete`` element mode must solve the
Euler-discretised problem exactly (QP oracle match).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Estimator, Problem, get_method, grid_lqt_from_linear, om_cost_linear,
    parallel_backward, parallel_rts, parallel_two_filter,
    qp_map_from_grid, sequential_backward, sequential_rts,
    sequential_two_filter, simulate_linear, time_grid,
)

from helpers import random_ltv, wiener_velocity


@pytest.fixture(scope="module")
def wiener_problem():
    model = wiener_velocity()
    T, n = 256, 10
    ts = time_grid(0.0, 5.0, T * n)
    xs, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    grid = grid_lqt_from_linear(model, ts, y)
    return model, ts, xs, y, grid, n


@pytest.fixture(scope="module")
def ltv_problem():
    model = random_ltv(jax.random.PRNGKey(7))
    T, n = 64, 5
    ts = time_grid(0.0, 4.0, T * n)
    xs, y = simulate_linear(model, ts, jax.random.PRNGKey(1))
    grid = grid_lqt_from_linear(model, ts, y)
    return model, ts, xs, y, grid, n


def test_discrete_parallel_equals_sequential_exactly(wiener_problem):
    _, _, _, _, grid, n = wiener_problem
    seq = sequential_rts(grid, "discrete")
    par = parallel_rts(grid, n, "discrete")
    np.testing.assert_allclose(par.x, seq.x, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(par.S, seq.S, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(par.v, seq.v, rtol=1e-9, atol=1e-9)


def test_discrete_matches_qp_oracle(ltv_problem):
    _, _, _, _, grid, n = ltv_problem
    x_qp = qp_map_from_grid(grid)
    par = parallel_rts(grid, n, "discrete")
    np.testing.assert_allclose(par.x, x_qp, rtol=1e-6, atol=1e-7)
    tf = parallel_two_filter(grid, n, "discrete")
    np.testing.assert_allclose(tf.x, x_qp, rtol=1e-6, atol=1e-7)


def test_euler_parallel_tracks_sequential(wiener_problem):
    """euler mode: parallel and sequential agree to the discretisation
    order (they are different O(dt^2)-local approximations, so the gap is
    the O(dt) GLOBAL euler discretisation error -- observed max ~1.8e-1
    at this dt, under 2% relative on the trajectory scale (~15).  3e-1 is
    the mode-appropriate bound; test_euler_convergence_rate pins the
    O(dt) decay so the bound cannot hide a broken discretisation."""
    _, _, _, _, grid, n = wiener_problem
    seq = sequential_rts(grid, "euler")
    par = parallel_rts(grid, n, "euler")
    assert float(jnp.max(jnp.abs(par.x - seq.x))) < 3e-1
    ref = parallel_rts(grid, n, "discrete")
    assert float(jnp.max(jnp.abs(par.x - ref.x))) < 3e-1


def test_euler_convergence_rate(wiener_problem):
    """halving dt must shrink the euler-vs-exact gap ~linearly or better."""
    model, _, _, _, _, _ = wiener_problem
    errs = []
    for T in (256, 512, 1024):
        n = 10
        ts = time_grid(0.0, 5.0, T * n)
        _, y = simulate_linear(model, ts, jax.random.PRNGKey(3))
        grid = grid_lqt_from_linear(model, ts, y)
        eu = parallel_rts(grid, n, "euler")
        ex = parallel_rts(grid, n, "discrete")
        errs.append(float(jnp.max(jnp.abs(eu.x - ex.x))))
    assert errs[2] < errs[1] < errs[0]
    assert errs[0] / errs[2] > 3.0, errs


def test_two_filter_equals_rts(wiener_problem):
    """eq. (39)/(48) two-filter recovery == eq. (47) forward recovery.

    In ``discrete`` mode both recoveries solve the same quadratic problem
    exactly -> tight tolerance; in ``euler`` mode they are two different
    O(dt^2)-local discretisations -> agreement only to the discretisation
    error scale (same magnitude and bound as the parallel-vs-sequential
    euler gap in ``test_euler_parallel_tracks_sequential``).
    """
    _, _, _, _, grid, n = wiener_problem
    for mode, atol in (("euler", 1e-1), ("discrete", 1e-5)):
        rts = parallel_rts(grid, n, mode)
        tf = parallel_two_filter(grid, n, mode)
        np.testing.assert_allclose(tf.x, rts.x, atol=atol)
        tf_mi = parallel_two_filter(grid, n, mode,
                                    block0_fill="min_initial")
        np.testing.assert_allclose(tf_mi.x, rts.x, atol=max(atol, 2e-4))


def test_two_filter_sequential_parity(wiener_problem):
    _, _, _, _, grid, n = wiener_problem
    seq = sequential_two_filter(grid, "discrete")
    par = parallel_two_filter(grid, n, "discrete",
                              block0_fill="min_initial")
    np.testing.assert_allclose(par.x, seq.x, rtol=1e-7, atol=1e-7)


def test_backward_is_kalman_bucy_information_filter(ltv_problem):
    """S, v from the parallel scan == sequential information recursion,
    i.e. the parallel Kalman-Bucy filter (paper sections 2.5, 4)."""
    _, _, _, _, grid, n = ltv_problem
    seq = sequential_backward(grid, "discrete")
    par, _, _, _ = parallel_backward(grid, n, "discrete")
    np.testing.assert_allclose(par.S, seq.S, rtol=1e-8, atol=1e-9)
    np.testing.assert_allclose(par.v, seq.v, rtol=1e-8, atol=1e-9)


def test_map_cost_optimality(ltv_problem):
    """the MAP estimate must beat perturbed trajectories in OM cost."""
    model, ts, _, y, grid, n = ltv_problem
    sol = parallel_rts(grid, n, "discrete")
    c_star = float(om_cost_linear(model, ts, y, sol.x))
    key = jax.random.PRNGKey(11)
    for k in jax.random.split(key, 4):
        pert = sol.x + 1e-2 * jax.random.normal(k, sol.x.shape)
        assert float(om_cost_linear(model, ts, y, pert)) > c_star


def test_smoothing_covariance_psd(wiener_problem):
    _, _, _, _, grid, n = wiener_problem
    tf = parallel_two_filter(grid, n, "discrete")
    cov = np.asarray(tf.cov)
    finite = np.isfinite(cov).all(axis=(1, 2))
    assert finite.sum() >= cov.shape[0] - (n - 1)  # block-0 interior NaN ok
    w = np.linalg.eigvalsh(cov[finite])
    assert w.min() > -1e-9


def test_batched_vmap_solvers(ltv_problem):
    """whole solver vmaps over a batch of measurement records."""
    model, ts, _, _, _, n = ltv_problem
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    ys = jnp.stack([simulate_linear(model, ts, k)[1] for k in keys])

    def solve(y):
        return parallel_rts(grid_lqt_from_linear(model, ts, y), n,
                            "discrete").x

    batched = jax.vmap(solve)(ys)
    for i in range(3):
        np.testing.assert_allclose(batched[i], solve(ys[i]),
                                   rtol=1e-9, atol=1e-9)


def test_estimator_covers_every_method(wiener_problem):
    model, ts, _, y, _, n = wiener_problem
    problem = Problem.single(model, ts, y)
    for method in ("parallel_rts", "parallel_two_filter",
                   "sequential_rts", "sequential_two_filter"):
        options = get_method(method).options_cls.from_legacy(
            nsub=n, mode="discrete")
        sol = Estimator(model, method=method, options=options).solve(problem)
        assert sol.x.shape == (len(ts), 4)
        assert bool(jnp.isfinite(sol.x).all())
        assert bool(jnp.isfinite(sol.cost))
