"""Shared wave machinery for the serving engines.

Both serving engines -- :class:`~repro.serving.TrajectoryEngine` (whole
offline records) and :class:`~repro.serving.StreamingEngine` (fixed-lag
sliding windows) -- batch work the same way: FIFO waves of exactly
``batch`` rows grouped by padded bucket length, short waves topped up by
recycling a live row, padded rows masked exactly (see
:mod:`repro.core.padding`).  This module is that machinery, factored out
so wave selection, padding/stacking and the wave-level obs metrics have
ONE implementation:

* :class:`WaveItem` -- one queued unit of work (a record or a window
  snapshot), optionally carrying a warm-start trajectory and an
  information-form prior for its left boundary;
* :func:`validate_record` -- shared submit-time shape + time-grid checks
  (strictly-increasing ``ts`` -- a non-monotone grid would silently
  extrapolate a broken padded grid, see :func:`repro.core.padding.pad_record`);
* :func:`take_wave` -- FIFO wave selection: the oldest item fixes the
  bucket, later same-bucket items top the wave up (continuous batching);
* :func:`pack_wave` -- pad + stack a wave into the arrays of one
  ``Problem.stacked`` solve (measurements, mask, per-row warm starts,
  per-row priors);
* :func:`record_wave_metrics` -- the per-wave obs readout under a metric
  prefix (``engine.*`` / ``stream.*`` -- taxonomy in
  docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.padding import pad_record
from repro.core.registry import get_method


def robust_default_options(method: str):
    """The serving engines' default solver options: the method's defaults
    with the ``discrete`` element mode.

    The core :class:`~repro.core.Estimator` defaults to the paper's
    ``euler`` element mode (explicit Euler on the backward HJB ODEs) --
    faithful to the paper's experiments, but EXPLICIT-EULER-UNSTABLE once
    a block's information Riccati gets stiff (small R / large ``nsub *
    dt``): block elements overflow and the combined estimate silently
    turns NaN (for the test Wiener-velocity model at dt = 0.1 this
    happens from 4 blocks of ``nsub=10`` up).  A serving engine cannot
    pick its record lengths, so it must not default to a mode whose
    stability depends on them: the engines default to the ``discrete``
    mode (exact substep composition -- unconditionally stable, parallel
    == sequential to round-off) and leave ``euler`` opt-in via
    ``options=``.

    Iterated nonlinear methods (``"sigma_point"``) take the ``discrete``
    mode on their INNER method's options -- the outer options keep their
    own defaults (iterations, linearisation family).
    """
    spec = get_method(method)
    if spec.nonlinear:
        outer = spec.options_cls()
        inner = get_method(outer.inner_method).options_cls(mode="discrete")
        return outer.replace(inner=inner)
    return spec.options_cls(mode="discrete")


@dataclasses.dataclass
class WaveItem:
    """One queued unit of work: a whole record or one window snapshot.

    ``key`` is the caller's handle (ticket / track id).  ``x_init`` is an
    optional warm-start trajectory covering the item's real grid
    (``(N+1, nx)``; padded rows repeat the final state).  ``prior`` is an
    optional information-form ``(S0, v0)`` left-boundary override.
    """

    key: int
    ts: np.ndarray
    y: np.ndarray
    n_pad: int
    submit_t: float = 0.0          # perf_counter at submit; latency readout
    x_init: Optional[np.ndarray] = None
    prior: Optional[Tuple[np.ndarray, np.ndarray]] = None


def validate_record(ts, y) -> Tuple[np.ndarray, np.ndarray]:
    """Shared submit-time validation: shapes and a strictly-increasing
    time grid.  Returns ``(ts, y)`` as numpy arrays."""
    ts = np.asarray(ts)
    y = np.asarray(y)
    if y.ndim != 2 or y.shape[0] < 1:
        raise ValueError(
            f"y must be (N, ny) with N >= 1, got shape {y.shape}")
    if ts.shape != (y.shape[0] + 1,):
        raise ValueError(
            f"ts must be (N+1,) = {(y.shape[0] + 1,)}, got {ts.shape}")
    if not np.all(np.diff(ts) > 0):
        raise ValueError(
            "ts must be strictly increasing (padding extrapolates the "
            f"grid with the final step, which a non-monotone or repeated "
            f"time point would corrupt); got ts={ts!r}")
    return ts, y


def take_wave(queue: Deque[WaveItem], batch: int) -> List[WaveItem]:
    """FIFO wave: the oldest item fixes the bucket; later same-bucket
    items top the wave up to ``batch`` (others keep their place).
    Scanning stops as soon as the wave is full, so draining Q queued
    items is O(Q), not O(Q^2/batch).  Mutates ``queue`` in place."""
    n_pad = queue[0].n_pad
    wave: List[WaveItem] = []
    keep: Deque[WaveItem] = collections.deque()
    while queue and len(wave) < batch:
        item = queue.popleft()
        if item.n_pad == n_pad:
            wave.append(item)
        else:
            keep.append(item)
    keep.extend(queue)                 # untouched tail, order preserved
    queue.clear()
    queue.extend(keep)
    return wave


def _pad_trajectory(x: np.ndarray, n_pad: int) -> np.ndarray:
    """Extend a warm-start trajectory ``(N+1, nx)`` to ``(n_pad+1, nx)``
    by repeating the final state (the padded tail follows the drift from
    there; the repeated point is only a linearisation/warm-start hint)."""
    extra = n_pad + 1 - x.shape[0]
    if extra <= 0:
        return x[:n_pad + 1]
    return np.concatenate([x, np.repeat(x[-1:], extra, axis=0)], axis=0)


def pack_wave(wave: List[WaveItem], batch: int):
    """Pad + stack a same-bucket wave into stacked-problem arrays.

    Returns ``(ts_b, ys_b, mask_b, x_init_b, prior_b)`` with exactly
    ``batch`` rows -- short waves recycle row 0.  ``x_init_b`` is a
    ``(batch, n_pad+1, nx)`` array when ANY item carries a warm start
    (items without one get their prior-mean-free default only if ALL lack
    it -- mixing is resolved by requiring the caller to be consistent);
    ``prior_b`` similarly stacks per-row ``(S0, v0)``.
    """
    n_pad = wave[0].n_pad
    padded = [pad_record(it.ts, it.y, n_pad) for it in wave]
    rows = padded + [padded[0]] * (batch - len(padded))
    ts_b = jnp.asarray(np.stack([r[0] for r in rows]))
    ys_b = jnp.asarray(np.stack([r[1] for r in rows]))
    mask_b = jnp.asarray(np.stack([r[2] for r in rows]))

    x_init_b = None
    if any(it.x_init is not None for it in wave):
        if not all(it.x_init is not None for it in wave):
            raise ValueError(
                "wave mixes items with and without warm-start trajectories")
        xi_rows = [_pad_trajectory(np.asarray(it.x_init), n_pad)
                   for it in wave]
        xi_rows += [xi_rows[0]] * (batch - len(xi_rows))
        x_init_b = jnp.asarray(np.stack(xi_rows))

    prior_b = None
    if any(it.prior is not None for it in wave):
        if not all(it.prior is not None for it in wave):
            raise ValueError(
                "wave mixes items with and without boundary priors")
        S_rows = [np.asarray(it.prior[0]) for it in wave]
        v_rows = [np.asarray(it.prior[1]) for it in wave]
        S_rows += [S_rows[0]] * (batch - len(S_rows))
        v_rows += [v_rows[0]] * (batch - len(v_rows))
        prior_b = (jnp.asarray(np.stack(S_rows)),
                   jnp.asarray(np.stack(v_rows)))
    return ts_b, ys_b, mask_b, x_init_b, prior_b


def record_wave_metrics(prefix: str, wave: List[WaveItem], n_pad: int,
                        batch: int, queue_depth: int) -> None:
    """Per-wave obs readout under ``prefix`` (``engine`` / ``stream``):
    waves/completed/recycled counters, interval-padding accounting, the
    cumulative ``<prefix>.padding_waste`` gauge, wave occupancy, queue
    depth and the per-item submit-to-done latency histogram."""
    now = time.perf_counter()
    real = sum(it.y.shape[0] for it in wave)
    solved = n_pad * batch
    obs.inc(f"{prefix}.waves")
    obs.inc(f"{prefix}.completed", len(wave))
    obs.inc(f"{prefix}.recycled_rows", batch - len(wave))
    obs.inc(f"{prefix}.real_intervals", real)
    obs.inc(f"{prefix}.padded_intervals", solved)
    obs.record(f"{prefix}.wave_occupancy", len(wave) / batch,
               buckets=[i / 20 for i in range(21)])
    # cumulative padding waste: fraction of solved intervals that were
    # padding or recycled rows (0 = perfect packing)
    c = obs.REGISTRY.counter
    total_real = c(f"{prefix}.real_intervals").value
    total_solved = c(f"{prefix}.padded_intervals").value
    if total_solved:
        obs.set_gauge(f"{prefix}.padding_waste",
                      1.0 - total_real / total_solved)
    obs.set_gauge(f"{prefix}.queue_depth", queue_depth)
    latency = ("engine.record_latency_seconds" if prefix == "engine"
               else f"{prefix}.window_latency_seconds")
    for it in wave:
        obs.record(latency, now - it.submit_t)
