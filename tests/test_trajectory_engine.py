"""TrajectoryEngine: queue semantics, wave bucketing, row recycling,
result correctness, and the sharded batch path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import wiener_velocity
from repro.core import (
    Estimator, ParallelOptions, Problem, SequentialOptions, simulate_linear,
    time_grid,
)
from repro.launch.mesh import make_host_mesh
from repro.serving import TrajectoryEngine

NSUB = 5
OPTIONS = ParallelOptions(nsub=NSUB, mode="discrete")


def _record(model, N, seed):
    ts = time_grid(0.0, N / 20.0, N)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(seed))
    return np.asarray(ts), np.asarray(y)


def _engine(model, **kw):
    kw.setdefault("batch", 4)
    kw.setdefault("options", OPTIONS)
    return TrajectoryEngine(model, **kw)


def test_submit_step_collect_cycle():
    model = wiener_velocity()
    engine = _engine(model)
    recs = [_record(model, 20, s) for s in range(6)]   # one bucket, 2 waves
    tickets = [engine.submit(ts, y) for ts, y in recs]
    assert tickets == list(range(6))
    assert engine.pending() == 6
    assert engine.collect() == []                      # nothing solved yet

    assert engine.step() == 4                          # first full wave
    assert engine.pending() == 2
    got = engine.collect()
    assert [t for t, _ in got] == tickets[:4]
    assert engine.collect() == []                      # collect() drains

    assert engine.run() == 2                           # second (short) wave
    assert [t for t, _ in engine.collect()] == tickets[4:]
    assert engine.step() == 0                          # empty queue
    assert engine.waves == 2
    assert engine.recycled_rows == 2                   # short wave padded


def test_results_match_direct_solve():
    model = wiener_velocity()
    engine = _engine(model, method="parallel_rts")
    recs = [_record(model, N, 10 + i)
            for i, N in enumerate([12, 20, 35, 20, 17])]
    sols = engine.estimate(recs)
    seq = Estimator(model, method="sequential_rts",
                    options=SequentialOptions(mode="discrete"))
    for (ts, y), sol in zip(recs, sols):
        assert sol.x.shape == (y.shape[0] + 1, model.nx)
        # nsub-free sequential reference handles the non-multiple-of-nsub
        # lengths; discrete mode makes it exact vs the parallel engine.
        ref = seq.solve(Problem.single(
            model, jnp.asarray(ts), jnp.asarray(y)))
        np.testing.assert_allclose(sol.x, ref.x, atol=1e-6, rtol=0)


def test_waves_group_by_bucket_fifo():
    """The oldest request fixes the wave's bucket; later same-bucket
    requests jump the queue (continuous batching), others keep order."""
    model = wiener_velocity()
    engine = _engine(model, batch=2)
    t0 = engine.submit(*_record(model, 12, 20))   # bucket 20
    t1 = engine.submit(*_record(model, 35, 21))   # bucket 40
    t2 = engine.submit(*_record(model, 18, 22))   # bucket 20

    assert engine.step() == 2                     # t0 + t2 share a wave
    assert sorted(t for t, _ in engine.collect()) == sorted([t0, t2])
    assert engine.step() == 1                     # then t1
    assert [t for t, _ in engine.collect()] == [t1]


def test_estimate_preserves_submission_order():
    model = wiener_velocity()
    engine = _engine(model, batch=2)
    recs = [_record(model, N, 30 + i)
            for i, N in enumerate([35, 12, 35, 12])]
    sols = engine.estimate(recs)
    for (ts, y), sol in zip(recs, sols):
        assert sol.x.shape[0] == y.shape[0] + 1


def test_submit_validation_and_config_errors():
    model = wiener_velocity()
    engine = _engine(model)
    ts, y = _record(model, 20, 40)
    with pytest.raises(ValueError):
        engine.submit(ts[:-1], y)                 # ts/y length mismatch
    with pytest.raises(ValueError):
        engine.submit(ts, y[:, 0])                # y not 2-D
    with pytest.raises(ValueError):
        TrajectoryEngine(model, batch=0)
    with pytest.raises(TypeError):                # unknown legacy kwarg
        TrajectoryEngine(model, n_sub=3)
    with pytest.raises(TypeError):                # options + legacy kwargs
        TrajectoryEngine(model, options=OPTIONS, nsub=3)


def test_sequential_engine_uses_unit_buckets():
    """Sequential methods have no block constraint: buckets are bare
    powers of two (block_size 1), not multiples of a default nsub."""
    model = wiener_velocity()
    engine = TrajectoryEngine(model, batch=2, method="sequential_rts")
    assert engine.estimator.block_size == 1
    engine.submit(*_record(model, 12, 60))
    assert engine._queue[0].n_pad == 16


def test_sharded_batch_path():
    """mesh from repro.launch.mesh: waves go through shard_map."""
    model = wiener_velocity()
    mesh = make_host_mesh()
    engine = _engine(model, batch=2 * mesh.shape["data"], mesh=mesh)
    recs = [_record(model, 20, 50 + i) for i in range(3)]
    sols = engine.estimate(recs)
    par = Estimator(model, method="parallel_rts", options=OPTIONS)
    for (ts, y), sol in zip(recs, sols):
        ref = par.solve(Problem.single(
            model, jnp.asarray(ts), jnp.asarray(y)))
        np.testing.assert_allclose(sol.x, ref.x, atol=1e-6, rtol=0)
