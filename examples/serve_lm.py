"""Serving example: batched generation with continuous batching.

Loads a trained checkpoint when one exists (from examples/train_lm.py),
else serves a fresh random-initialised smoke model.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.config import get_config
from repro.models import transformer
from repro.serving.engine import Request, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw_init


def main():
    cfg = get_config("smollm-135m-smoke")
    params = transformer.init(cfg, jax.random.PRNGKey(0))

    latest = ckpt.latest_checkpoint("/tmp/repro_train_lm")
    if latest:
        full = get_config("smollm-135m")
        p_like = transformer.init(full, jax.random.PRNGKey(0))
        try:
            _, (params, _) = ckpt.restore_checkpoint(
                latest, (p_like, adamw_init(p_like)))
            cfg = full
            print(f"[serve] loaded {latest}")
        except Exception as e:
            print(f"[serve] checkpoint mismatch ({e}); using smoke model")

    engine = ServeEngine(cfg, params, batch=4, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=16)
                    .astype(np.int32), max_new_tokens=12)
            for _ in range(8)]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {tokens} tokens, "
          f"{tokens / dt:.1f} tok/s")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: {r.prompt[:5]}... -> {r.out}")


if __name__ == "__main__":
    main()
