"""TrajectoryEngine: queue semantics, wave bucketing, row recycling,
result correctness, and the sharded batch path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import wiener_velocity
from repro.core import (
    Estimator, ParallelOptions, Problem, SequentialOptions, simulate_linear,
    time_grid,
)
from repro.launch.mesh import make_host_mesh
from repro.serving import TrajectoryEngine

NSUB = 5
OPTIONS = ParallelOptions(nsub=NSUB, mode="discrete")


def _record(model, N, seed):
    ts = time_grid(0.0, N / 20.0, N)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(seed))
    return np.asarray(ts), np.asarray(y)


def _engine(model, **kw):
    kw.setdefault("batch", 4)
    kw.setdefault("options", OPTIONS)
    return TrajectoryEngine(model, **kw)


def test_submit_step_collect_cycle():
    model = wiener_velocity()
    engine = _engine(model)
    recs = [_record(model, 20, s) for s in range(6)]   # one bucket, 2 waves
    tickets = [engine.submit(ts, y) for ts, y in recs]
    assert tickets == list(range(6))
    assert engine.pending() == 6
    assert engine.collect() == []                      # nothing solved yet

    assert engine.step() == 4                          # first full wave
    assert engine.pending() == 2
    got = engine.collect()
    assert [t for t, _ in got] == tickets[:4]
    assert engine.collect() == []                      # collect() drains

    assert engine.run() == 2                           # second (short) wave
    assert [t for t, _ in engine.collect()] == tickets[4:]
    assert engine.step() == 0                          # empty queue
    assert engine.waves == 2
    assert engine.recycled_rows == 2                   # short wave padded


def test_results_match_direct_solve():
    model = wiener_velocity()
    engine = _engine(model, method="parallel_rts")
    recs = [_record(model, N, 10 + i)
            for i, N in enumerate([12, 20, 35, 20, 17])]
    sols = engine.estimate(recs)
    seq = Estimator(model, method="sequential_rts",
                    options=SequentialOptions(mode="discrete"))
    for (ts, y), sol in zip(recs, sols):
        assert sol.x.shape == (y.shape[0] + 1, model.nx)
        # nsub-free sequential reference handles the non-multiple-of-nsub
        # lengths; discrete mode makes it exact vs the parallel engine.
        ref = seq.solve(Problem.single(
            model, jnp.asarray(ts), jnp.asarray(y)))
        np.testing.assert_allclose(sol.x, ref.x, atol=1e-6, rtol=0)


def test_waves_group_by_bucket_fifo():
    """The oldest request fixes the wave's bucket; later same-bucket
    requests jump the queue (continuous batching), others keep order."""
    model = wiener_velocity()
    engine = _engine(model, batch=2)
    t0 = engine.submit(*_record(model, 12, 20))   # bucket 20
    t1 = engine.submit(*_record(model, 35, 21))   # bucket 40
    t2 = engine.submit(*_record(model, 18, 22))   # bucket 20

    assert engine.step() == 2                     # t0 + t2 share a wave
    assert sorted(t for t, _ in engine.collect()) == sorted([t0, t2])
    assert engine.step() == 1                     # then t1
    assert [t for t, _ in engine.collect()] == [t1]


def test_estimate_preserves_submission_order():
    model = wiener_velocity()
    engine = _engine(model, batch=2)
    recs = [_record(model, N, 30 + i)
            for i, N in enumerate([35, 12, 35, 12])]
    sols = engine.estimate(recs)
    for (ts, y), sol in zip(recs, sols):
        assert sol.x.shape[0] == y.shape[0] + 1


def test_submit_validation_and_config_errors():
    model = wiener_velocity()
    engine = _engine(model)
    ts, y = _record(model, 20, 40)
    with pytest.raises(ValueError):
        engine.submit(ts[:-1], y)                 # ts/y length mismatch
    with pytest.raises(ValueError):
        engine.submit(ts, y[:, 0])                # y not 2-D
    with pytest.raises(ValueError):
        TrajectoryEngine(model, batch=0)
    with pytest.raises(TypeError):                # unknown legacy kwarg
        TrajectoryEngine(model, n_sub=3)
    with pytest.raises(TypeError):                # options + legacy kwargs
        TrajectoryEngine(model, options=OPTIONS, nsub=3)


def test_sequential_engine_uses_unit_buckets():
    """Sequential methods have no block constraint: buckets are bare
    powers of two (block_size 1), not multiples of a default nsub."""
    model = wiener_velocity()
    engine = TrajectoryEngine(model, batch=2, method="sequential_rts")
    assert engine.estimator.block_size == 1
    engine.submit(*_record(model, 12, 60))
    assert engine._queue[0].n_pad == 16


def test_sharded_batch_path():
    """mesh from repro.launch.mesh: waves go through shard_map."""
    model = wiener_velocity()
    mesh = make_host_mesh()
    engine = _engine(model, batch=2 * mesh.shape["data"], mesh=mesh)
    recs = [_record(model, 20, 50 + i) for i in range(3)]
    sols = engine.estimate(recs)
    par = Estimator(model, method="parallel_rts", options=OPTIONS)
    for (ts, y), sol in zip(recs, sols):
        ref = par.solve(Problem.single(
            model, jnp.asarray(ts), jnp.asarray(y)))
        np.testing.assert_allclose(sol.x, ref.x, atol=1e-6, rtol=0)


def test_submit_rejects_non_monotone_ts():
    """Regression: a non-monotone / repeated time grid used to be padded
    silently (the padded tail extrapolates with dt_last, so a reversed or
    zero final step produced a broken problem); it must fail at submit."""
    model = wiener_velocity()
    engine = _engine(model)
    ts, y = _record(model, 12, 70)
    bad = ts.copy()
    bad[5], bad[6] = bad[6], bad[5]                  # swap -> non-monotone
    with pytest.raises(ValueError, match="strictly increasing"):
        engine.submit(bad, y)
    with pytest.raises(ValueError, match="strictly increasing"):
        engine.submit(np.concatenate([ts[:-1], ts[-2:-1]]), y)  # repeat
    assert engine.pending() == 0                     # nothing half-queued


def test_collect_ticket_filter_prevents_races():
    """Regression: collect() popped EVERYTHING, so a concurrent collector
    could steal another client's results between its run() and collect().
    collect(tickets=...) pops only those tickets."""
    model = wiener_velocity()
    engine = _engine(model, batch=2)
    t_a = engine.submit(*_record(model, 12, 80))
    t_b = engine.submit(*_record(model, 12, 81))
    engine.run()
    got_b = engine.collect(tickets=[t_b])
    assert [t for t, _ in got_b] == [t_b]
    # A's result is still there for A, plus unknown tickets are ignored
    got_a = engine.collect(tickets=[t_a, 999])
    assert [t for t, _ in got_a] == [t_a]
    assert engine.collect() == []                    # nothing left behind


def test_estimate_explains_unredeemable_tickets():
    model = wiener_velocity()
    engine = _engine(model, batch=2)
    ticket = engine.submit(*_record(model, 12, 90))
    engine.run()
    thief = engine.collect()                          # steals everything
    assert [t for t, _ in thief] == [ticket]
    assert "already collected" in engine.describe_ticket(ticket)
    assert "never issued" in engine.describe_ticket(12345)
    queued = engine.submit(*_record(model, 12, 91))
    assert "queued" in engine.describe_ticket(queued)
    engine.run()
    assert "finished" in engine.describe_ticket(queued)


def test_default_options_are_numerically_robust():
    """Regression: the engine default inherited the Estimator's euler
    element mode, which silently NaNs on long-enough records (explicit
    Euler on a stiff block Riccati -- 40+ intervals of the dt=0.1
    Wiener-velocity model).  The serving default is now the discrete
    mode; long records must stay finite."""
    model = wiener_velocity()
    engine = TrajectoryEngine(model, batch=2)        # options=None
    ts = time_grid(0.0, 8.0, 80)                     # dt = 0.1
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(99))
    [sol] = engine.estimate([(np.asarray(ts), np.asarray(y))])
    assert np.isfinite(np.asarray(sol.x)).all()
