"""The ``method="distributed"`` surface: options validation, MeshSpec,
single-device fallback (in-process -- tests see ONE device, see
conftest.py), and 8-forced-host-device agreement/cache-fingerprint suites
(subprocess-isolated, ``distributed`` marker)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.wiener_velocity import WienerVelocityConfig
from repro.core import (
    DistributedOptions,
    Estimator,
    ExecutableCache,
    ParallelOptions,
    Problem,
    method_names,
    simulate_linear,
    time_grid,
)
from repro.distributed import MeshSpec, as_mesh, mesh_fingerprint


@pytest.fixture(scope="module")
def lin_problem():
    cfg = WienerVelocityConfig(p0=1.0)
    model = cfg.model()
    ts = time_grid(cfg.t0, cfg.tf, 200)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    return model, ts, y


# ---------------------------------------------------------------------------
# construction-time validation (single device, in-process)
# ---------------------------------------------------------------------------


def test_method_registered():
    assert "distributed" in method_names()


def test_options_defaults_and_validation():
    o = DistributedOptions()
    assert (o.time_axis, o.batch_axes) == ("time", ("data",))
    assert o.devices_per_time is None
    assert o.resolve_carry_dtype() is None
    assert DistributedOptions(
        carry_dtype="float64").resolve_carry_dtype() == jnp.float64
    # batch_axes list form is normalised to a tuple (hashable: options
    # are part of the executable-cache key)
    assert DistributedOptions(batch_axes=["b"]).batch_axes == ("b",)
    hash(DistributedOptions(batch_axes=["b"]))

    with pytest.raises(ValueError, match="time_axis"):
        DistributedOptions(time_axis="")
    with pytest.raises(ValueError, match="batch_axes"):
        DistributedOptions(batch_axes=("ok", ""))
    with pytest.raises(ValueError, match="cannot also be a batch axis"):
        DistributedOptions(time_axis="t", batch_axes=("t",))
    with pytest.raises(ValueError, match="devices_per_time"):
        DistributedOptions(devices_per_time=0)
    with pytest.raises(ValueError, match="carry_dtype"):
        DistributedOptions(carry_dtype="bf16")
    with pytest.raises(ValueError, match="fallback"):
        DistributedOptions(fallback="maybe")
    with pytest.raises(ValueError, match="nsub"):
        DistributedOptions(nsub=0)          # inherited ParallelOptions check
    with pytest.raises(TypeError):
        DistributedOptions(shard_count=4)   # unknown names fail at init


def test_meshspec_validation():
    spec = MeshSpec(time=2, batch=3)
    assert spec.num_devices == 6
    with pytest.raises(ValueError, match="positive int"):
        MeshSpec(time=0)
    with pytest.raises(ValueError, match="positive int"):
        MeshSpec(batch=-1)
    with pytest.raises(ValueError, match="non-empty str"):
        MeshSpec(time_axis="")
    with pytest.raises(ValueError, match="must differ"):
        MeshSpec(time_axis="x", batch_axis="x")
    # more devices than this process has -> loud error at build
    with pytest.raises(ValueError, match="devices"):
        MeshSpec(time=max(2 * len(jax.devices()), 4096)).build()


def test_as_mesh_normalisation():
    assert as_mesh(None) is None
    mesh = MeshSpec().build()
    assert as_mesh(mesh) is mesh
    built = as_mesh(MeshSpec())
    assert tuple(built.axis_names) == ("time", "data")
    with pytest.raises(TypeError, match="MeshSpec"):
        as_mesh("time:8")


def test_mesh_fingerprint():
    assert mesh_fingerprint(None) is None
    fp = mesh_fingerprint(MeshSpec().build())
    assert fp[0] == ("time", "data") and fp[1] == (1, 1)
    assert mesh_fingerprint(MeshSpec().build()) == fp          # value-based
    assert mesh_fingerprint(
        MeshSpec(time_axis="T").build()) != fp
    hash(fp)


# ---------------------------------------------------------------------------
# single-device fallback (in-process: exactly one device)
# ---------------------------------------------------------------------------


def test_fallback_auto_matches_parallel(lin_problem):
    model, ts, y = lin_problem
    p = Problem.single(model, ts, y)
    sd = Estimator(model, method="distributed",
                   options=DistributedOptions(mode="discrete"),
                   cache=ExecutableCache()).solve(p)
    sp = Estimator(model, method="parallel_rts",
                   options=ParallelOptions(mode="discrete"),
                   cache=ExecutableCache()).solve(p)
    # the fallback IS the parallel solver: bit-exact, not just close
    np.testing.assert_array_equal(np.asarray(sd.x), np.asarray(sp.x))
    np.testing.assert_array_equal(np.asarray(sd.S), np.asarray(sp.S))


def test_fallback_error_raises(lin_problem):
    model, ts, y = lin_problem
    est = Estimator(model, method="distributed",
                    options=DistributedOptions(fallback="error"),
                    cache=ExecutableCache())
    with pytest.raises(RuntimeError, match="needs >= 2 devices"):
        est.solve(Problem.single(model, ts, y))


def test_devices_per_time_exceeding_available_raises(lin_problem):
    model, ts, y = lin_problem
    est = Estimator(
        model, method="distributed",
        options=DistributedOptions(
            devices_per_time=2 * len(jax.devices())),
        cache=ExecutableCache())
    with pytest.raises(ValueError, match="exceeds"):
        est.solve(Problem.single(model, ts, y))


# ---------------------------------------------------------------------------
# 8 forced host devices (subprocess-isolated)
# ---------------------------------------------------------------------------

_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax
assert len(jax.devices()) == 8, jax.devices()
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.configs.wiener_velocity import WienerVelocityConfig
from repro.core import (DistributedOptions, Estimator, ParallelOptions,
                        Problem, SequentialOptions, cache_stats,
                        clear_cache, simulate_linear, time_grid)
from repro.distributed import MeshSpec

cfg = WienerVelocityConfig(p0=1.0)
model = cfg.model()
opts = DistributedOptions(mode="discrete")
ts = time_grid(cfg.t0, cfg.tf, 520)   # 52 blocks + terminal: 53 elems,
_, y = simulate_linear(model, ts, jax.random.PRNGKey(0))  # 53 % 8 != 0
dist = Estimator(model, method="distributed", options=opts)
par = Estimator(model, method="parallel_rts",
                options=ParallelOptions(mode="discrete"))

def close(a, b, tol=1e-9):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)
"""


def _run(snippet: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _COMMON + textwrap.dedent(snippet)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return out.stdout


@pytest.mark.slow
@pytest.mark.distributed
def test_agreement_all_layouts_8_devices():
    out = _run("""
    # --- single, non-divisible T vs shard count, vs parallel + sequential
    p = Problem.single(model, ts, y)
    sd = dist.solve(p)
    sp = par.solve(p)
    close(sd.x, sp.x); close(sd.S, sp.S); close(sd.v, sp.v)
    seq = Estimator(model, method="sequential_rts",
                    options=SequentialOptions(mode="discrete"))
    ss = seq.solve(p)
    close(sd.x, ss.x, 1e-7)

    # --- masked measurements (dropout pattern)
    mask = (np.arange(520) % 3 != 0).astype(float)
    pm = Problem.single(model, ts, y, measurement_mask=mask)
    close(dist.solve(pm).x, par.solve(pm).x)

    # --- stacked (+ per-record masks), time-only default mesh
    ys = jnp.stack([y, y * 1.1, y * 0.9, y + 0.1])
    masks = jnp.asarray(np.stack([mask, 1 - mask, mask, np.ones(520)]))
    ps = Problem.stacked(model, ts, ys, measurement_mask=masks)
    close(dist.solve(ps).x, par.solve(ps).x)

    # --- ragged buckets (unequal lengths -> pad-and-bucket)
    recs = []
    for N in (130, 250, 520):
        tsr = time_grid(cfg.t0, cfg.tf, N)
        _, yr = simulate_linear(model, tsr, jax.random.PRNGKey(N))
        recs.append((np.asarray(tsr), np.asarray(yr)))
    pr = Problem.ragged(model, recs)
    for a, b in zip(dist.solve(pr), par.solve(pr)):
        close(a.x, b.x)
        assert a.padding is not None

    # --- obs: distributed.shards / carry_bytes counters + scan span
    import repro.obs as obs
    obs.enable(); obs.reset()
    ts2 = time_grid(cfg.t0, cfg.tf, 480)     # new length -> fresh trace
    _, y2 = simulate_linear(model, ts2, jax.random.PRNGKey(7))
    dist.solve(Problem.single(model, ts2, y2))
    snap = obs.snapshot(include_trees=True)
    # two sharded scans per solve (backward LQT + forward affine)
    assert snap["counters"]["distributed.shards"] == 16, snap["counters"]
    assert snap["counters"]["distributed.carry_bytes"] > 0
    names = set()
    def walk(nodes):
        for nd in nodes:
            names.add(nd["name"]); walk(nd.get("children", []))
    walk(snap["span_trees"])
    assert "distributed_scan" in names, names
    print("LAYOUTS-OK")
    """)
    assert "LAYOUTS-OK" in out


@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_surface_and_cache_fingerprint_8_devices():
    out = _run("""
    from repro.serving import TrajectoryEngine

    p = Problem.single(model, ts, y)
    ref = par.solve(p)
    ys = jnp.stack([y, y * 1.1, y * 0.9, y + 0.1])
    ps = Problem.stacked(model, ts, ys)
    ref_s = par.solve(ps)

    # --- explicit 2-D (time x batch) MeshSpec
    est2 = Estimator(model, method="distributed", options=opts,
                     mesh=MeshSpec(time=4, batch=2))
    close(est2.solve(ps).x, ref_s.x)
    # batch not divisible by the mesh batch axis -> loud error
    try:
        est2.solve(Problem.stacked(model, ts, jnp.stack([y, y, y])))
        raise AssertionError("expected ValueError")
    except ValueError as e:
        assert "not divisible" in str(e)

    # --- AOT lower() under the mesh
    aot = est2.lower(ps).compile()
    close(aot(ts, ys).x, ref_s.x)

    # --- ambient mesh via MeshSpec.activate(); the executable-cache key
    # fingerprints the RESOLVED mesh, so the same Estimator never replays
    # an executable compiled under a different ambient mesh.
    clear_cache()
    est = Estimator(model, method="distributed", options=opts)
    with MeshSpec(time=8).activate():
        close(est.solve(p).x, ref.x)
    with MeshSpec(time=4).activate():
        close(est.solve(p).x, ref.x)
    st = cache_stats()
    assert st["misses"] == 2 and st["hits"] == 0, st
    # replaying under a previously seen mesh IS a hit
    with MeshSpec(time=8).activate():
        close(est.solve(p).x, ref.x)
    assert cache_stats()["hits"] == 1, cache_stats()

    # --- devices_per_time mismatch with the ambient mesh is an error
    bad = Estimator(model, method="distributed",
                    options=DistributedOptions(mode="discrete",
                                               devices_per_time=2))
    with MeshSpec(time=8).activate():
        try:
            bad.solve(p)
            raise AssertionError("expected ValueError")
        except ValueError as e:
            assert "devices_per_time" in str(e)

    # --- TrajectoryEngine on the unified mesh entry point
    eng = TrajectoryEngine(model, batch=2, method="distributed",
                           options=opts, mesh=MeshSpec(time=4, batch=2))
    recs = [(np.asarray(ts), np.asarray(y)),
            (np.asarray(ts), np.asarray(y) * 1.1)]
    sols = eng.estimate(recs)
    close(sols[0].x, ref.x)
    print("MESH-SURFACE-OK")
    """)
    assert "MESH-SURFACE-OK" in out
