"""Batched multi-trajectory MAP estimation: parallelism over the REQUEST axis.

The paper parallelises a single estimation problem over time; production
serving additionally wants many independent problems solved as one compiled
program.  This module provides that layer:

* :func:`map_estimate_batched` -- ``vmap`` of :func:`~repro.core.api.
  map_estimate` over stacked measurement records (linear and nonlinear
  models, all registered methods), optionally ``shard_map``-sharded over a
  mesh axis so the batch spreads across devices.
* :func:`map_estimate_ragged` -- pad-and-bucket front-end for records of
  unequal length: each record is padded to a bucket length (a power-of-two
  number of ``nsub``-substep blocks) with masked-out measurements, so a
  handful of executables serves any mix of lengths.
* an explicit executable cache keyed by
  ``(model, batch shape, method, nsub, mode, ...)`` -- one trace per key,
  inspectable via :func:`cache_stats` (the bucketing above keeps the key
  space small).

Padding is EXACT, not approximate: a padded tail beyond ``t_f`` carries
``measurement_mask = 0`` so it contributes no measurement cost, and the
dynamics cost of the tail is zero at the optimum (the extension follows the
drift), hence the MAP estimate restricted to the real window is unchanged
(see :func:`~repro.core.sde.build_grid_lqt`).  Tests verify padded == unpadded
to round-off.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .api import map_estimate
from .sde import LinearSDE, NonlinearSDE
from .types import MAPSolution

Model = Union[LinearSDE, NonlinearSDE]


# ---------------------------------------------------------------------------
# Executable cache
# ---------------------------------------------------------------------------


class _ExecutableCache:
    """LRU cache of jitted batched solvers keyed by (model, shapes, method,
    nsub, mode, iterations, divergence_correction, mesh, batch_axis).

    Models are frozen dataclasses holding arrays (unhashable), so the key
    uses ``id(model)``; a strong reference to the model (and mesh) is kept
    in the entry so the id cannot be recycled while cached.  ``maxsize``
    bounds retained executables/models: callers constructing a fresh model
    per request never hit (new id each time) and would otherwise grow the
    cache without bound -- reuse one model object to get executable reuse.
    """

    def __init__(self, maxsize: int = 128) -> None:
        self._entries: "collections.OrderedDict[tuple, tuple]" = (
            collections.OrderedDict())
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0

    def get(self, model: Model, mesh, key_tail: tuple,
            build) -> "jax.stages.Wrapped":
        key = (id(model), None if mesh is None else id(mesh)) + key_tail
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return entry[0]
        self.misses += 1
        fn = build()
        self._entries[key] = (fn, model, mesh)
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return fn

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)


_CACHE = _ExecutableCache()


def cache_stats() -> Dict[str, int]:
    """Executable-cache counters: one miss per compiled (shape, method,
    nsub, mode, ...) combination, hits for every reuse."""
    return {"size": len(_CACHE), "hits": _CACHE.hits, "misses": _CACHE.misses}


def clear_cache() -> None:
    _CACHE.clear()


# ---------------------------------------------------------------------------
# Batched entry point
# ---------------------------------------------------------------------------


def map_estimate_batched(
    model: Model,
    ts: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
    measurement_mask: Optional[jnp.ndarray] = None,
    mesh=None,
    batch_axis: str = "data",
) -> MAPSolution:
    """Solve a stacked batch of estimation problems as one compiled program.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`.
      ts: time grid, shared ``(N+1,)`` or per-record ``(B, N+1)``.
      ys: stacked measurement records ``(B, N, ny)``.
      measurement_mask: optional ``(B, N)`` of 0/1 -- masked intervals
        contribute no measurement information (padding / missing data).
      mesh: optional ``jax.sharding.Mesh``; when given the batch axis is
        sharded over ``mesh.shape[batch_axis]`` devices with ``shard_map``
        (``B`` must be divisible by that axis size).

    Returns a :class:`MAPSolution` whose fields carry a leading batch axis.
    """
    ys = jnp.asarray(ys)
    if ys.ndim != 3:
        raise ValueError(f"ys must be (B, N, ny), got shape {ys.shape}")
    ts = jnp.asarray(ts)
    ts_batched = ts.ndim == 2
    B, N = ys.shape[0], ys.shape[1]
    if ts.shape[-1] != N + 1:
        raise ValueError(
            f"ts has {ts.shape[-1]} points but ys has {N} intervals "
            f"(need N+1 = {N + 1})")
    if ts_batched and ts.shape[0] != B:
        raise ValueError(f"ts batch {ts.shape[0]} != ys batch {B}")
    masked = measurement_mask is not None
    if masked:
        measurement_mask = jnp.asarray(measurement_mask)
        if measurement_mask.shape != (B, N):
            raise ValueError(
                f"measurement_mask must be {(B, N)}, got "
                f"{measurement_mask.shape}")
    if mesh is not None:
        axis = mesh.shape[batch_axis]
        if B % axis:
            raise ValueError(
                f"batch {B} not divisible by mesh axis {batch_axis!r} "
                f"size {axis}")

    key_tail = (ts.shape, ys.shape, str(ys.dtype), masked, method, nsub,
                mode, iterations, divergence_correction, batch_axis)

    def build():
        if masked:
            def solve_one(t, y, m):
                return map_estimate(
                    model, t, y, method=method, nsub=nsub, mode=mode,
                    iterations=iterations,
                    divergence_correction=divergence_correction,
                    measurement_mask=m)
            in_axes = (0 if ts_batched else None, 0, 0)
        else:
            def solve_one(t, y):
                return map_estimate(
                    model, t, y, method=method, nsub=nsub, mode=mode,
                    iterations=iterations,
                    divergence_correction=divergence_correction)
            in_axes = (0 if ts_batched else None, 0)
        fn = jax.vmap(solve_one, in_axes=in_axes)
        if mesh is not None:
            from repro.distributed.sharding import shard_over_batch
            fn = shard_over_batch(
                fn, mesh, batch_axis,
                (ts_batched, True) + ((True,) if masked else ()))
        return jax.jit(fn)

    fn = _CACHE.get(model, mesh, key_tail, build)
    args = (ts, ys) + ((measurement_mask,) if masked else ())
    return fn(*args)


# ---------------------------------------------------------------------------
# Pad-and-bucket for ragged record lengths
# ---------------------------------------------------------------------------


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def bucket_length(
    N: int, nsub: int, bucket_sizes: Optional[Sequence[int]] = None,
) -> int:
    """Padded interval count for a record of ``N`` intervals.

    Default rule: the smallest power-of-two number of ``nsub``-substep
    blocks that fits, i.e. ``nsub * 2^ceil(log2(N / nsub))`` -- always a
    multiple of ``nsub`` (required by the parallel methods' blocking) and
    at most ~2x overhead.  Explicit ``bucket_sizes`` (multiples of
    ``nsub``) override the rule; the smallest fitting bucket is used.
    """
    if bucket_sizes is not None:
        for size in bucket_sizes:
            if size % nsub:
                raise ValueError(
                    f"bucket size {size} not a multiple of nsub={nsub}")
        fitting = [s for s in bucket_sizes if s >= N]
        if not fitting:
            raise ValueError(
                f"record length {N} exceeds largest bucket "
                f"{max(bucket_sizes)}")
        return min(fitting)
    blocks = -(-N // nsub)          # ceil
    return nsub * _next_pow2(blocks)


def pad_record(
    ts: np.ndarray, y: np.ndarray, n_pad: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad one record to ``n_pad`` intervals.

    The time grid is extended past ``t_f`` with the final step size, padded
    measurements are zeros, and the returned mask marks them as carrying no
    information.  Returns ``(ts_pad (n_pad+1,), y_pad (n_pad, ny),
    mask (n_pad,))``.
    """
    ts = np.asarray(ts)
    y = np.asarray(y)
    N = y.shape[0]
    if N < 1:
        raise ValueError("record must have at least one interval")
    if ts.shape[0] != N + 1:
        raise ValueError(f"ts has {ts.shape[0]} points for {N} intervals")
    if n_pad < N:
        raise ValueError(f"n_pad={n_pad} < record length {N}")
    extra = n_pad - N
    dt_last = ts[-1] - ts[-2]
    ts_pad = np.concatenate(
        [ts, ts[-1] + dt_last * np.arange(1, extra + 1, dtype=ts.dtype)])
    y_pad = np.concatenate(
        [y, np.zeros((extra,) + y.shape[1:], dtype=y.dtype)], axis=0)
    mask = np.concatenate(
        [np.ones(N, dtype=y.dtype), np.zeros(extra, dtype=y.dtype)])
    return ts_pad, y_pad, mask


def slice_solution(sol: MAPSolution, row: int, N: int) -> MAPSolution:
    """Extract record ``row`` from a batched solution, un-padded to ``N``
    intervals (``N+1`` trajectory points)."""
    take = lambda a: None if a is None else a[row, :N + 1]
    return MAPSolution(x=take(sol.x), S=take(sol.S), v=take(sol.v),
                       cov=take(sol.cov))


def map_estimate_ragged(
    model: Model,
    records: Sequence[Tuple[np.ndarray, np.ndarray]],
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
    bucket_sizes: Optional[Sequence[int]] = None,
    pad_batch: bool = True,
    mesh=None,
    batch_axis: str = "data",
) -> List[MAPSolution]:
    """Solve records of unequal length via pad-and-bucket batching.

    ``records`` is a sequence of ``(ts_i, y_i)`` pairs with ``ts_i``
    ``(N_i+1,)`` and ``y_i`` ``(N_i, ny)``.  Records are grouped by padded
    length (:func:`bucket_length`), each bucket is solved with ONE batched
    call (batch padded to a power of two when ``pad_batch``, recycling row
    0, so executables are shared across calls with different record
    counts), and results are un-padded and returned in input order.
    """
    buckets: Dict[int, List[int]] = {}
    lengths: List[int] = []
    for i, (ts_i, y_i) in enumerate(records):
        N_i = np.asarray(y_i).shape[0]
        lengths.append(N_i)
        n_pad = bucket_length(N_i, nsub, bucket_sizes)
        buckets.setdefault(n_pad, []).append(i)

    out: List[Optional[MAPSolution]] = [None] * len(records)
    for n_pad, idxs in sorted(buckets.items()):
        padded = [pad_record(records[i][0], records[i][1], n_pad)
                  for i in idxs]
        B = len(padded)
        B_pad = _next_pow2(B) if pad_batch else B
        if mesh is not None:
            axis = mesh.shape[batch_axis]
            B_pad = -(-B_pad // axis) * axis
        rows = padded + [padded[0]] * (B_pad - B)   # recycle row 0
        ts_b = jnp.asarray(np.stack([r[0] for r in rows]))
        ys_b = jnp.asarray(np.stack([r[1] for r in rows]))
        mask_b = jnp.asarray(np.stack([r[2] for r in rows]))
        sol = map_estimate_batched(
            model, ts_b, ys_b, method=method, nsub=nsub, mode=mode,
            iterations=iterations,
            divergence_correction=divergence_correction,
            measurement_mask=mask_b, mesh=mesh, batch_axis=batch_axis)
        for row, i in enumerate(idxs):
            out[i] = slice_solution(sol, row, lengths[i])
    return out  # type: ignore[return-value]
