"""Mamba2 (SSD) mixer -- built on the paper's affine scan.

The SSD recurrence h_t = exp(dt A) h_{t-1} + dt x_t (x) B_t IS the paper's
trajectory recursion (eqs. 45-46) with diagonal transition; the chunked
training path reuses the same block-element decomposition: per-chunk
elements (decay, state-increment) folded by an associative combine
(``repro.core.combine.affine_combine`` specialised to diagonal Phi), with
the intra-chunk part dense.  ``repro.kernels.ssd`` is the TPU kernel of the
same algorithm; this module is the shardable pure-JAX path used by the
dry-run and CPU smoke tests.

Layer structure follows mamba2: in_proj -> [z | x | B | C | dt], short
depthwise conv on (x,B,C), SSD scan, gated RMSNorm, out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import logical_constraint

from .layers import P, rms_norm


def ssm_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    din = cfg.ssm_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    H = cfg.ssm_heads
    conv_dim = din + 2 * gs
    common = {
        "A_log": P((H,), ("ssm_heads",), init="ones"),
        "D_skip": P((H,), ("ssm_heads",), init="ones"),
        "dt_bias": P((H,), ("ssm_heads",), init="zeros"),
        "gate_norm": P((din,), ("ssm_inner",), init="ones"),
        "w_out": P((din, D), ("ssm_inner", "embed")),
    }
    if cfg.ssm_fused_proj:
        return {
            "w_in": P((D, 2 * din + 2 * gs + H), ("embed", "ssm_x")),
            "conv_w": P((cfg.ssm_conv, conv_dim), (None, "ssm_x"),
                        fan_in=cfg.ssm_conv),
            "conv_b": P((conv_dim,), ("ssm_x",), init="zeros"),
            **common,
        }
    # split projections: every stream sharded on its own clean axis
    # (no splits/concats of model-sharded dims -> no halo exchanges;
    # EXPERIMENTS.md SPerf mamba2 iteration)
    return {
        "w_z": P((D, din), ("embed", "ssm_inner")),
        "w_x": P((D, din), ("embed", "ssm_inner")),
        "w_B": P((D, gs), ("embed", "ssm_x")),
        "w_C": P((D, gs), ("embed", "ssm_x")),
        "w_dt": P((D, H), ("embed", "ssm_heads")),
        "conv_x_w": P((cfg.ssm_conv, din), (None, "ssm_inner"),
                      fan_in=cfg.ssm_conv),
        "conv_x_b": P((din,), ("ssm_inner",), init="zeros"),
        "conv_B_w": P((cfg.ssm_conv, gs), (None, "ssm_x"),
                      fan_in=cfg.ssm_conv),
        "conv_B_b": P((gs,), ("ssm_x",), init="zeros"),
        "conv_C_w": P((cfg.ssm_conv, gs), (None, "ssm_x"),
                      fan_in=cfg.ssm_conv),
        "conv_C_b": P((gs,), ("ssm_x",), init="zeros"),
        **common,
    }


class SSMCache(NamedTuple):
    """Decode-time state: conv tail + SSD state (O(1) in context length)."""
    conv: jnp.ndarray    # (B, conv_k - 1, conv_dim)
    state: jnp.ndarray   # (B, H, P, S) f32


def _split_proj(cfg: ModelConfig, zxbcdt):
    din = cfg.ssm_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + gs, 2 * din + 2 * gs], axis=-1)
    return z, xs, B, C, dt


def ssd_scan_jnp(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD in pure JAX: the paper's block-element + scan pattern.

    Stage 1 builds per-chunk elements in parallel (the paper's per-block
    element init), stage 2 folds them with an ASSOCIATIVE prefix scan
    (eqs. 45-46, diagonal Phi), stage 3 emits per-chunk outputs under
    ``lax.map`` so the (Q, Q, H) decay tensors exist one chunk at a time
    (memory-bounded for 4k/32k sequences).

    x: (b, L, H, P); dt: (b, L, H); A: (H,); B, C: (b, L, G, S); D: (H,).
    """
    from repro.core.pscan import prefix_scan

    b, L0, H, Pd = x.shape
    G, S = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, L0)
    pad = (-L0) % Q
    if pad:  # dt=0 padding steps are exact identity elements
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = L0 + pad
    nc = L // Q

    f32 = jnp.float32
    l = (dt.astype(f32) * A.astype(f32)[None, None, :])       # (b, L, H)
    dtx = dt.astype(f32)[..., None] * x.astype(f32)           # (b, L, H, P)

    # chunk-major views (chunk axis FIRST for scan/map)
    lc = jnp.moveaxis(l.reshape(b, nc, Q, H), 1, 0)           # (nc,b,Q,H)
    cum = jnp.cumsum(lc, axis=2)
    total = cum[:, :, -1]                                     # (nc,b,H)
    dtxc = jnp.moveaxis(dtx.reshape(b, nc, Q, H, Pd), 1, 0)
    Bc = jnp.moveaxis(B.astype(f32).reshape(b, nc, Q, G, S), 1, 0)
    Cc = jnp.moveaxis(C.astype(f32).reshape(b, nc, Q, G, S), 1, 0)

    # stage 1 -- per-chunk elements (parallel over chunks):
    w = jnp.exp(total[:, :, None] - cum)[..., None] * dtxc    # (nc,b,Q,H,P)
    wg = w.reshape(nc, b, Q, G, rep, Pd)
    inc = jnp.einsum("nbqgrp,nbqgs->nbgrps", wg, Bc)
    inc = inc.reshape(nc, b, H, Pd, S)                        # (nc,b,H,P,S)

    # stage 2 -- associative inter-chunk scan (paper eqs. 45-46):
    def combine(e1, e2):
        t1, i1 = e1
        t2, i2 = e2
        return (t1 + t2, jnp.exp(t2)[..., None, None] * i1 + i2)

    tot_in, inc_in = prefix_scan(combine, (total, inc))
    # exclusive prefix: state entering chunk c
    h_prev = jnp.concatenate(
        [jnp.zeros((1, b, H, Pd, S), f32), inc_in[:-1]], axis=0)

    # stage 3 -- per-chunk outputs, one chunk in flight at a time:
    ids = jnp.arange(Q)
    causal = ids[:, None] >= ids[None, :]

    def emit(args):
        cumc, dtxk, Bk, Ck, hk = args
        # inter: y_t = exp(cum_t) * C_t . h_prev
        hg = hk.reshape(b, G, rep, Pd, S)
        y_inter = jnp.einsum("bqgs,bgrps->bqgrp", Ck, hg)
        y_inter = y_inter * jnp.exp(cumc).reshape(b, Q, G, rep, 1)
        # intra: masked decay kernel
        Gmat = jnp.einsum("bqgs,bkgs->bgqk", Ck, Bk)          # (b,G,Q,Q)
        dec = jnp.exp(cumc[:, :, None, :] - cumc[:, None, :, :])
        dec = jnp.where(causal[None, :, :, None], dec, 0.0)   # (b,Q,Q,H)
        decg = dec.reshape(b, Q, Q, G, rep)
        M = Gmat.transpose(0, 2, 3, 1)[..., None] * decg      # (b,Q,Q,G,rep)
        dtxg = dtxk.reshape(b, Q, G, rep, Pd)
        y_intra = jnp.einsum("bqkgr,bkgrp->bqgrp", M, dtxg)
        return (y_inter + y_intra).reshape(b, Q, H, Pd)

    ys = jax.lax.map(emit, (cum, dtxc, Bc, Cc, h_prev))       # (nc,b,Q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, L, H, Pd)
    y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y[:, :L0].astype(x.dtype)


def _project_streams(params, x, cfg: ModelConfig):
    """in_proj + causal conv + silu -> (z, x, B, C, dt) streams."""
    din = cfg.ssm_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    if cfg.ssm_fused_proj:
        zxbcdt = jnp.einsum("bld,dk->blk", x, params["w_in"])
        zxbcdt = logical_constraint(zxbcdt, "batch", None, "ssm_x")
        z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
        xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xbc = jax.nn.silu(xbc)
        xs, Bm, Cm = jnp.split(xbc, [din, din + gs], axis=-1)
        return z, xs, Bm, Cm, dt
    z = jnp.einsum("bld,dk->blk", x, params["w_z"])
    xs = jnp.einsum("bld,dk->blk", x, params["w_x"])
    Bm = jnp.einsum("bld,dk->blk", x, params["w_B"])
    Cm = jnp.einsum("bld,dk->blk", x, params["w_C"])
    dt = jnp.einsum("bld,dk->blk", x, params["w_dt"])
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x_w"],
                                  params["conv_x_b"]))
    Bm = jax.nn.silu(_causal_conv(Bm, params["conv_B_w"],
                                  params["conv_B_b"]))
    Cm = jax.nn.silu(_causal_conv(Cm, params["conv_C_w"],
                                  params["conv_C_b"]))
    return z, xs, Bm, Cm, dt


def ssm_forward(params, x, cfg: ModelConfig, *, use_kernel: bool = False,
                interpret: bool = False):
    """Full-sequence mamba2 block.  x: (B, L, D) -> (B, L, D)."""
    Bb, L, _ = x.shape
    z, xs, Bm, Cm, dt = _project_streams(params, x, cfg)

    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xs.reshape(Bb, L, H, Pd)
    Bg = Bm.reshape(Bb, L, cfg.ssm_groups, cfg.ssm_state)
    Cg = Cm.reshape(Bb, L, cfg.ssm_groups, cfg.ssm_state)
    dth = jax.nn.softplus(dt + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if use_kernel:
        from repro.kernels.ssd import ssd_trainable
        y = ssd_trainable(xh, dth, A, Bg, Cg, params["D_skip"],
                          cfg.ssm_chunk, interpret)
    else:
        y = ssd_scan_jnp(xh, dth, A, Bg, Cg, params["D_skip"],
                         cfg.ssm_chunk)
    y = y.reshape(Bb, L, cfg.ssm_inner)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, params["w_out"])
    return logical_constraint(out, "batch", None, None)


def preconv_streams(params, x, cfg: ModelConfig):
    """in_proj only (no conv/silu): (z, x, B, C, dt), each (B, L, *)."""
    if cfg.ssm_fused_proj:
        zxbcdt = jnp.einsum("bld,dk->blk", x, params["w_in"])
        return _split_proj(cfg, zxbcdt)
    return (jnp.einsum("bld,dk->blk", x, params["w_z"]),
            jnp.einsum("bld,dk->blk", x, params["w_x"]),
            jnp.einsum("bld,dk->blk", x, params["w_B"]),
            jnp.einsum("bld,dk->blk", x, params["w_C"]),
            jnp.einsum("bld,dk->blk", x, params["w_dt"]))


def conv_cat_weights(params, cfg: ModelConfig):
    """(K, conv_dim) depthwise kernel over the concatenated (x, B, C)
    streams (decode-cache layout is stream-concatenated in both modes)."""
    if cfg.ssm_fused_proj:
        return params["conv_w"], params["conv_b"]
    w = jnp.concatenate(
        [params["conv_x_w"], params["conv_B_w"], params["conv_C_w"]],
        axis=1)
    b = jnp.concatenate(
        [params["conv_x_b"], params["conv_B_b"], params["conv_C_b"]],
        axis=0)
    return w, b


def ssm_decode(params, x, cfg: ModelConfig, cache: SSMCache):
    """One-token mamba2 step.  x: (B, 1, D)."""
    Bb = x.shape[0]
    z, xs, Bm, Cm, dt = preconv_streams(params, x, cfg)
    z, xs, Bm, Cm, dt = (a[:, 0] for a in (z, xs, Bm, Cm, dt))
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)      # (B, conv_dim)

    conv_hist = jnp.concatenate([cache.conv, xbc[:, None]], axis=1)
    w, bconv = conv_cat_weights(params, cfg)           # (K, conv_dim)
    out = jnp.einsum("bkc,kc->bc", conv_hist, w) + bconv
    xbc = jax.nn.silu(out)
    new_conv = conv_hist[:, 1:]

    din = cfg.ssm_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    xs, Bm, Cm = jnp.split(xbc, [din, din + gs], axis=-1)
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    G, S = cfg.ssm_groups, cfg.ssm_state
    rep = H // G
    xh = xs.reshape(Bb, H, Pd).astype(jnp.float32)
    Bg = Bm.reshape(Bb, G, S).astype(jnp.float32)
    Cg = Cm.reshape(Bb, G, S).astype(jnp.float32)
    dth = jax.nn.softplus(dt + params["dt_bias"][None]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    a = jnp.exp(dth * A[None])                         # (B, H)
    Bh = jnp.repeat(Bg, rep, axis=1)                   # (B, H, S)
    Ch = jnp.repeat(Cg, rep, axis=1)
    state = (a[..., None, None] * cache.state
             + (dth[..., None] * xh)[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhps,bhs->bhp", state, Ch)
    y = y + params["D_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bb, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, params["w_out"])[:, None]
    return out, SSMCache(new_conv, state)


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, L, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k:k + x.shape[1]].astype(jnp.float32) * w[k]
    return (out + b).astype(x.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    conv_dim = cfg.ssm_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                  jnp.float32))
