"""Iterated linearisation for nonlinear models (section 4.4).

Continuous-time iterated extended Kalman smoother: linearise (1) about the
current nominal trajectory, solve the resulting linear-affine MAP problem
with the sequential or PARALLEL smoother, re-linearise, repeat.  Every
iteration is parallel-in-time when ``method`` is a parallel solver, which is
exactly the paper's Fig.-2 experiment (5 iterations on the coordinated-turn
model).

The default drops the second-order Onsager-Machlup divergence correction
(as the paper's IEKS does -- for linear-affine subproblems div f~ is
constant); ``divergence_correction=True`` folds the linearised 1/2 div f
term in as an extra linear running cost (DESIGN.md S1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .registry import get_solver
from .sde import NonlinearSDE, grid_lqt_from_nonlinear
from .types import MAPSolution


def iterated_map(
    model: NonlinearSDE,
    ts: jnp.ndarray,
    y: jnp.ndarray,
    *,
    iterations: int = 5,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    divergence_correction: bool = False,
    x_init: jnp.ndarray | None = None,
    measurement_mask: Optional[jnp.ndarray] = None,
) -> MAPSolution:
    """Continuous-time iterated MAP estimation (paper section 5.2).

    ``iterations`` fixed Gauss-Newton style passes (paper uses 5); the
    initial nominal trajectory defaults to the constant prior mean.
    ``x_init`` may be a full nominal trajectory ``(N+1, nx)`` or a single
    state ``(nx,)`` that is broadcast along time -- the latter is the
    batch-friendly form (a per-record warm-start point vmaps over records
    of any padded length).  ``measurement_mask`` (``(N,)`` of 0/1) zeroes
    masked measurement intervals in every linearisation pass (padding /
    missing data).  Returns the MAP solution from the final linearisation.
    """
    solver = get_solver(method)
    N = y.shape[0]
    if x_init is None:
        x_init = jnp.broadcast_to(model.m0, (N + 1,) + model.m0.shape)
    elif x_init.ndim == 1:
        x_init = jnp.broadcast_to(x_init, (N + 1,) + x_init.shape)

    def body(xbar, _):
        grid = grid_lqt_from_nonlinear(
            model, ts, y, xbar, divergence_correction=divergence_correction,
            measurement_mask=measurement_mask)
        sol = solver(grid, nsub, mode)
        return sol.x, None

    # iterations-1 passes inside lax.scan (keeps the compiled graph O(1) in
    # iteration count), plus one final pass returning the full solution --
    # ``iterations`` linearise+solve passes total, matching the paper.
    x_last, _ = jax.lax.scan(body, x_init, None, length=iterations - 1)
    grid = grid_lqt_from_nonlinear(
        model, ts, y, x_last, divergence_correction=divergence_correction,
        measurement_mask=measurement_mask)
    return solver(grid, nsub, mode)
