"""The BENCH_*.json artifact schema and the benchmarks/compare.py
regression gate: schema validation, the committed seed baseline, and the
gate's warn/fail split (timing warn-only, cache-hit-rate and
padding-waste hard-fail).

These tests are pure-python (no solver runs): the gate logic must be
checkable without paying a benchmark run.
"""
import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.compare import cache_hit_rate, compare, main as compare_main
from repro import obs


def make_record(**over):
    """A minimal valid schema-v1 record with deterministic obs metrics."""
    rec = {
        "schema_version": obs.SCHEMA_VERSION,
        "benchmark": "smoke",
        "seeds": {"fig1": 0, "serve": 0},
        "env": {"python": "3.x", "jax": "0.0"},
        "rows": [
            {"name": "fig1/seq/T16", "us_per_call": 100.0, "derived": "s=1"},
            {"name": "serve/engine/B4_R8", "us_per_call": 2000.0,
             "derived": "tracks_per_sec=100"},
        ],
        "obs": {
            "counters": {"cache.hits": 8, "cache.misses": 2},
            "gauges": {"engine.padding_waste": 0.20},
            "histograms": {},
            "dropped_records": 0,
        },
    }
    rec.update(over)
    return rec


# -- schema validation ------------------------------------------------------


def test_valid_record_passes():
    assert obs.validate_bench(make_record()) == []


def test_bench_record_builder_is_valid():
    rows = [{"name": "a/b", "us_per_call": 1.5, "derived": "x=1"}]
    rec = obs.bench_record("unit", rows, seeds={"a": 0})
    assert obs.validate_bench(rec) == []
    assert rec["schema_version"] == obs.SCHEMA_VERSION
    assert rec["rows"][0]["us_per_call"] == 1.5
    assert "env" in rec and "obs" in rec


@pytest.mark.parametrize("mutate, fragment", [
    (lambda r: r.update(schema_version=99), "schema_version"),
    (lambda r: r.pop("rows"), "rows"),
    (lambda r: r.pop("env"), "env"),
    (lambda r: r["rows"][0].pop("us_per_call"), "us_per_call"),
    (lambda r: r["obs"].pop("counters"), "obs.counters"),
])
def test_invalid_records_are_rejected(mutate, fragment):
    rec = make_record()
    mutate(rec)
    problems = obs.validate_bench(rec)
    assert problems
    assert any(fragment in p for p in problems)


def test_write_bench_json_round_trips_and_validates(tmp_path):
    path = tmp_path / "sub" / "BENCH_unit.json"
    obs.write_bench_json(str(path), make_record())
    assert obs.validate_bench(json.loads(path.read_text())) == []
    with pytest.raises(ValueError, match="invalid benchmark record"):
        obs.write_bench_json(str(path), {"schema_version": 99})


def test_committed_seed_baseline_is_valid():
    """The baseline CI gates against must always satisfy the schema and
    carry the deterministic hard-gate metrics."""
    path = (Path(__file__).resolve().parent.parent
            / "benchmarks" / "baselines" / "BENCH_seed.json")
    rec = json.loads(path.read_text())
    assert obs.validate_bench(rec) == []
    assert cache_hit_rate(rec) is not None
    assert "engine.padding_waste" in rec["obs"]["gauges"]
    assert any(r["name"].startswith("serve/") for r in rec["rows"])


# -- the regression gate ----------------------------------------------------


def test_compare_identical_records_pass():
    base = make_record()
    hard, warn = compare(base, copy.deepcopy(base),
                         tolerance=0.5, hard_tolerance=0.02)
    assert hard == [] and warn == []


def test_timing_regression_warns_only():
    base = make_record()
    new = copy.deepcopy(base)
    new["rows"][0]["us_per_call"] *= 2.0          # 2x > 1.5x tolerance
    hard, warn = compare(base, new, tolerance=0.5, hard_tolerance=0.02)
    assert hard == []
    assert len(warn) == 1 and "timing regression" in warn[0]
    # --timing-hard upgrades the same finding to a failure
    hard, warn = compare(base, new, tolerance=0.5, hard_tolerance=0.02,
                         timing_hard=True)
    assert len(hard) == 1 and warn == []


def test_cache_hit_rate_drop_hard_fails():
    base = make_record()
    new = copy.deepcopy(base)
    new["obs"]["counters"]["cache.hits"] = 5      # 0.8 -> 0.714
    hard, _ = compare(base, new, tolerance=0.5, hard_tolerance=0.02)
    assert any("cache hit rate" in m for m in hard)
    # within hard_tolerance: no failure
    hard, _ = compare(base, new, tolerance=0.5, hard_tolerance=0.2)
    assert hard == []


def test_padding_waste_increase_hard_fails():
    base = make_record()
    new = copy.deepcopy(base)
    new["obs"]["gauges"]["engine.padding_waste"] = 0.30
    hard, _ = compare(base, new, tolerance=0.5, hard_tolerance=0.02)
    assert any("padding waste" in m for m in hard)


def test_missing_row_and_missing_metrics_hard_fail():
    base = make_record()
    new = copy.deepcopy(base)
    new["rows"] = new["rows"][:1]                 # serve row vanished
    del new["obs"]["counters"]["cache.hits"]
    del new["obs"]["gauges"]["engine.padding_waste"]
    hard, _ = compare(base, new, tolerance=0.5, hard_tolerance=0.02)
    assert any("row missing" in m for m in hard)
    assert any("counters missing" in m for m in hard)
    assert any("gauge missing" in m for m in hard)


def test_compare_cli_exit_codes(tmp_path, capsys):
    base_p = tmp_path / "base.json"
    obs.write_bench_json(str(base_p), make_record())

    assert compare_main([str(base_p), "--against", str(base_p)]) == 0
    assert "OK" in capsys.readouterr().out

    bad = make_record()
    bad["obs"]["counters"]["cache.hits"] = 0
    bad_p = tmp_path / "bad.json"
    obs.write_bench_json(str(bad_p), bad)
    assert compare_main([str(bad_p), "--against", str(base_p)]) == 1
    assert "FAIL" in capsys.readouterr().out

    (tmp_path / "broken.json").write_text("{not json")
    assert compare_main([str(tmp_path / "broken.json"),
                         "--against", str(base_p)]) == 2
