"""Pad-and-bucket utilities for ragged record lengths.

Records of unequal length are padded to a small set of bucket lengths so a
handful of compiled executables serves any mix of lengths.  Padding is
EXACT, not approximate: a padded tail beyond ``t_f`` carries
``measurement_mask = 0`` so it contributes no measurement cost, and the
dynamics cost of the tail is zero at the optimum (the extension follows
the drift), hence the MAP estimate restricted to the real window is
unchanged (see :func:`repro.core.sde.build_grid_lqt`); tests verify
padded == unpadded to round-off.

Used by :meth:`repro.core.Estimator.solve` on ragged
:class:`~repro.core.Problem`\\ s and by
:class:`repro.serving.TrajectoryEngine`.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import numpy as np

from .types import MAPSolution, Solution


def next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def bucket_length(
    N: int, nsub: int, bucket_sizes: Optional[Sequence[int]] = None,
) -> int:
    """Padded interval count for a record of ``N`` intervals.

    Default rule: the smallest power-of-two number of ``nsub``-substep
    blocks that fits, i.e. ``nsub * 2^ceil(log2(N / nsub))`` -- always a
    multiple of ``nsub`` (required by the parallel methods' blocking) and
    at most ~2x overhead.  Explicit ``bucket_sizes`` (multiples of
    ``nsub``) override the rule; the smallest fitting bucket is used.
    """
    if bucket_sizes is not None:
        for size in bucket_sizes:
            if size % nsub:
                raise ValueError(
                    f"bucket size {size} not a multiple of nsub={nsub}")
        fitting = [s for s in bucket_sizes if s >= N]
        if not fitting:
            raise ValueError(
                f"record length {N} exceeds largest bucket "
                f"{max(bucket_sizes)}")
        return min(fitting)
    blocks = -(-N // nsub)          # ceil
    return nsub * next_pow2(blocks)


def pad_record(
    ts: np.ndarray, y: np.ndarray, n_pad: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad one record to ``n_pad`` intervals.

    The time grid is extended past ``t_f`` with the final step size, padded
    measurements are zeros, and the returned mask marks them as carrying no
    information.  Returns ``(ts_pad (n_pad+1,), y_pad (n_pad, ny),
    mask (n_pad,))``.
    """
    ts = np.asarray(ts)
    y = np.asarray(y)
    N = y.shape[0]
    if N < 1:
        raise ValueError("record must have at least one interval")
    if ts.shape[0] != N + 1:
        raise ValueError(f"ts has {ts.shape[0]} points for {N} intervals")
    if n_pad < N:
        raise ValueError(f"n_pad={n_pad} < record length {N}")
    if not np.all(np.diff(ts) > 0):
        # the padded grid extrapolates with dt_last = ts[-1] - ts[-2]; a
        # non-increasing grid would silently produce a broken (reversed /
        # zero-step) padded tail, so fail loudly here instead.
        raise ValueError(
            "ts must be strictly increasing to pad (the padded grid "
            f"extends past t_f with the final step size); got ts={ts!r}")
    extra = n_pad - N
    dt_last = ts[-1] - ts[-2]
    ts_pad = np.concatenate(
        [ts, ts[-1] + dt_last * np.arange(1, extra + 1, dtype=ts.dtype)])
    y_pad = np.concatenate(
        [y, np.zeros((extra,) + y.shape[1:], dtype=y.dtype)], axis=0)
    mask = np.concatenate(
        [np.ones(N, dtype=y.dtype), np.zeros(extra, dtype=y.dtype)])
    return ts_pad, y_pad, mask


def slice_solution(
    sol: Union[Solution, MAPSolution], row: int, N: int,
) -> Union[Solution, MAPSolution]:
    """Extract record ``row`` from a batched solution, un-padded to ``N``
    intervals (``N+1`` trajectory points).

    Time-indexed fields (``x``/``S``/``v``/``cov``) are sliced; per-record
    diagnostics of a :class:`~repro.core.Solution` (``cost``,
    ``cost_trace``) keep the whole row.
    """
    take = lambda a: None if a is None else a[row, :N + 1]
    if isinstance(sol, Solution):
        per_record = lambda a: None if a is None else a[row]
        return Solution(
            x=take(sol.x), S=take(sol.S), v=take(sol.v), cov=take(sol.cov),
            cost=per_record(sol.cost),
            cost_trace=per_record(sol.cost_trace),
            step_norms=per_record(sol.step_norms),
            padding=sol.padding)
    return MAPSolution(x=take(sol.x), S=take(sol.S), v=take(sol.v),
                       cov=take(sol.cov))
