"""The kernel-backed ``parallel_kernel`` method: shared verification
harness asserting ``parallel_kernel == parallel == sequential`` across
precisions, state dims, grid lengths (incl. non-power-of-two scan lengths
that force lane padding), masks and ragged buckets -- plus the registry /
options / cache semantics the new backend must honour.

Compile budget note: every distinct (layout, options) pair compiles a
fresh kernel-scan executable (~15s under the Pallas interpreter), so the
suite shares one module-scoped wiener model/data and leans on the
module-level executable cache instead of re-deriving fixtures per test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import coordinated_turn, random_ltv, wiener_velocity
from repro.core import (
    Estimator,
    ExecutableCache,
    IteratedOptions,
    KernelOptions,
    ParallelOptions,
    Problem,
    SequentialOptions,
    cache_stats,
    get_method,
    method_names,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)

pytestmark = pytest.mark.kernel_interpret

NSUB = 5
N = 20                       # T+1 = 5 scan elements: non-pow2, lane pad -> 8

KOPTS = KernelOptions(nsub=NSUB, mode="discrete", interpret=True)
POPTS = ParallelOptions(nsub=NSUB, mode="discrete")


def _max_abs(a, b):
    return float(jnp.max(jnp.abs(jnp.asarray(a) - jnp.asarray(b))))


def _assert_sol_close(got, ref, *, precision="default"):
    """parallel_kernel vs a jnp method, tolerance per kernel precision.

    ``x`` is held to the acceptance-criteria max-abs bound; the
    information-form ``S``/``v`` grow with the horizon, so those use
    relative tolerances at the same precision level.
    """
    if precision == "float32":
        assert _max_abs(got.x, ref.x) < 1e-5
        rtol, atol = 2e-5, 1e-5
    else:
        assert _max_abs(got.x, ref.x) < 1e-8
        rtol, atol = 1e-9, 1e-8
    np.testing.assert_allclose(np.asarray(got.S), np.asarray(ref.S),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(got.v), np.asarray(ref.v),
                               rtol=rtol, atol=atol)


@pytest.fixture(scope="module")
def wiener():
    """One shared model instance + data: the executable cache keys on the
    model object, so every test reusing this fixture (and KOPTS) reuses
    ONE compiled kernel executable per layout."""
    model = wiener_velocity()
    ts = time_grid(0.0, 1.0, N)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    return model, ts, y


@pytest.fixture(scope="module")
def wiener_refs(wiener):
    """Reference solutions of the jnp parallel + sequential methods."""
    model, ts, y = wiener
    problem = Problem.single(model, ts, y)
    par = Estimator(model, method="parallel_rts", options=POPTS).solve(problem)
    seq = Estimator(model, method="sequential_rts",
                    options=SequentialOptions(mode="discrete")).solve(problem)
    return par, seq


# ---------------------------------------------------------------------------
# the shared equivalence harness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", ["default", "float32"])
def test_parallel_kernel_matches_parallel_and_sequential(wiener, wiener_refs,
                                                         precision):
    model, ts, y = wiener
    par, seq = wiener_refs
    got = Estimator(
        model, method="parallel_kernel",
        options=KOPTS.replace(precision=precision),
    ).solve(Problem.single(model, ts, y))
    _assert_sol_close(got, par, precision=precision)
    # transitivity anchor: jnp parallel == sequential to round-off, so the
    # kernel method agrees with the sequential baseline too.
    assert _max_abs(par.x, seq.x) < 1e-8
    _assert_sol_close(got, seq, precision=precision)


@pytest.mark.parametrize("case", [
    # (model key, N intervals, nsub, block_size) -- T+1 scan elements:
    ("wiener", 40, 5, 8),     # nx=4, 9 elems: multi-block grid + lane pad
    ("ltv", 24, 4, 512),      # nx=3, 7 elems, time-varying F/c
], ids=["wiener-n40-b8", "ltv-n24"])
def test_parallel_kernel_across_dims_and_lengths(case):
    key, n, nsub, block_size = case
    model = wiener_velocity() if key == "wiener" else \
        random_ltv(jax.random.PRNGKey(2))
    ts = time_grid(0.0, 1.0, n)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(n))
    problem = Problem.single(model, ts, y)
    got = Estimator(model, method="parallel_kernel",
                    options=KernelOptions(nsub=nsub, mode="discrete",
                                          interpret=True,
                                          block_size=block_size)
                    ).solve(problem)
    ref = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=nsub, mode="discrete")
                    ).solve(problem)
    _assert_sol_close(got, ref)


def test_parallel_kernel_with_measurement_mask(wiener):
    model, ts, y = wiener
    mask = jnp.ones(N).at[8:14].set(0.0)           # a missing-data gap
    problem = Problem.single(model, ts, y, measurement_mask=mask)
    got = Estimator(model, method="parallel_kernel",
                    options=KOPTS).solve(problem)
    ref = Estimator(model, method="parallel_rts",
                    options=POPTS).solve(problem)
    _assert_sol_close(got, ref)
    # and the mask actually changed the answer vs the unmasked solve
    unmasked = Estimator(model, method="parallel_kernel",
                         options=KOPTS).solve(Problem.single(model, ts, y))
    assert _max_abs(got.x, unmasked.x) > 1e-6


def test_parallel_kernel_stacked_non_pow2_batch(wiener):
    """B=3 stacked records: the vmapped Pallas call and per-record
    correctness (each row must match its own single solve)."""
    model, ts, y = wiener
    ys = jnp.stack([y] + [simulate_linear(model, ts, jax.random.PRNGKey(k))[1]
                          for k in (1, 2)])
    est = Estimator(model, method="parallel_kernel", options=KOPTS)
    sol = est.solve(Problem.stacked(model, ts, ys))
    assert sol.x.shape == (3, N + 1, model.nx)
    for b in range(3):
        one = est.solve(Problem.single(model, ts, ys[b]))
        assert _max_abs(sol.x[b], one.x) < 1e-10


def test_parallel_kernel_ragged_buckets(wiener):
    """Unequal record lengths -> pad-and-bucket, one kernel executable per
    bucket; each record matches the jnp parallel method's ragged solve."""
    model, _, _ = wiener
    lengths = [14, 20, 40]                       # two distinct buckets
    recs = []
    for i, n in enumerate(lengths):
        ts_i = time_grid(0.0, 0.05 * n, n)
        _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(10 + i))
        recs.append((np.asarray(ts_i), np.asarray(y_i)))
    got = Estimator(model, method="parallel_kernel",
                    options=KOPTS).solve(Problem.ragged(model, recs))
    ref = Estimator(model, method="parallel_rts",
                    options=POPTS).solve(Problem.ragged(model, recs))
    assert len(got) == len(lengths)
    for g, r, n in zip(got, ref, lengths):
        assert g.x.shape == (n + 1, model.nx)
        assert _max_abs(g.x, r.x) < 1e-8
        assert g.padding is not None
    assert len(got[0].padding.buckets) == 2


def test_parallel_kernel_nonlinear_coordinated_turn():
    """Iterated linearisation with the kernel backend solving every inner
    linearised subproblem (the acceptance-criteria config pair), incl.
    the float32 kernel precision staying inside the 1e-5 envelope."""
    ct = coordinated_turn()
    ts = time_grid(0.0, 1.0, N)
    _, y = simulate_nonlinear(ct, ts, jax.random.PRNGKey(3))
    problem = Problem.single(ct, ts, y)
    ref = Estimator(ct, method="parallel_rts",
                    options=IteratedOptions(
                        iterations=2,
                        inner=ParallelOptions(nsub=NSUB))).solve(problem)
    got = Estimator(ct, method="parallel_kernel",
                    options=IteratedOptions(
                        iterations=2,
                        inner=KernelOptions(nsub=NSUB, interpret=True))
                    ).solve(problem)
    assert _max_abs(got.x, ref.x) < 1e-8
    got32 = Estimator(ct, method="parallel_kernel",
                      options=IteratedOptions(
                          iterations=2,
                          inner=KernelOptions(nsub=NSUB, interpret=True,
                                              precision="float32"))
                      ).solve(problem)
    assert _max_abs(got32.x, ref.x) < 1e-5


def test_parallel_kernel_euler_mode(wiener):
    """euler elements differ from discrete ones; the kernel scan must
    track the jnp scan in that mode too (same elements, same tree)."""
    model, ts, y = wiener
    problem = Problem.single(model, ts, y)
    got = Estimator(model, method="parallel_kernel",
                    options=KOPTS.replace(mode="euler")).solve(problem)
    ref = Estimator(model, method="parallel_rts",
                    options=POPTS.replace(mode="euler")).solve(problem)
    _assert_sol_close(got, ref)


# ---------------------------------------------------------------------------
# registry / options / cache semantics of the new backend
# ---------------------------------------------------------------------------


def test_kernel_options_validation():
    with pytest.raises(TypeError):
        KernelOptions(block=128)                  # unknown field
    with pytest.raises(TypeError):
        KernelOptions(blocksize=128)              # typo'd field
    with pytest.raises(ValueError, match="block_size"):
        KernelOptions(block_size=4)
    with pytest.raises(ValueError, match="precision"):
        KernelOptions(precision="float16")
    with pytest.raises(ValueError, match="interpret"):
        KernelOptions(interpret=1)
    with pytest.raises(ValueError, match="nsub"):
        KernelOptions(nsub=0)                     # inherited validation
    with pytest.raises(ValueError, match="mode"):
        KernelOptions(mode="bogus")
    # frozen + hashable (cache-key requirement)
    o = KernelOptions(nsub=5, block_size=128, precision="float32")
    assert hash(o) == hash(KernelOptions(nsub=5, block_size=128,
                                         precision="float32"))
    assert o.replace(block_size=256).block_size == 256


def test_kernel_options_interpret_resolution():
    assert KernelOptions(interpret=True).resolve_interpret() is True
    assert KernelOptions(interpret=False).resolve_interpret() is False
    # auto mode: interpret everywhere except a real TPU backend
    auto = KernelOptions().resolve_interpret()
    assert auto is (jax.default_backend() != "tpu")


def test_parallel_kernel_registered_and_in_live_methods_view():
    assert "parallel_kernel" in method_names()
    spec = get_method("parallel_kernel")
    assert spec.options_cls is KernelOptions
    assert isinstance(spec.default_options(), KernelOptions)
    import repro.core
    with pytest.warns(DeprecationWarning, match="METHODS"):
        live = repro.core.METHODS
    assert "parallel_kernel" in live


def test_parallel_kernel_cache_key_bit_exact(wiener):
    """Two solves with identical options must reuse ONE executable and
    return bit-identical arrays; the shared module cache keys on the
    options value, not the instance."""
    model, ts, y = wiener
    problem = Problem.single(model, ts, y)
    a = Estimator(model, method="parallel_kernel", options=KOPTS
                  ).solve(problem)
    mid = cache_stats()
    b = Estimator(model, method="parallel_kernel",
                  options=KernelOptions(nsub=NSUB, mode="discrete",
                                        interpret=True)).solve(problem)
    after = cache_stats()
    assert after["misses"] == mid["misses"]    # equal options: no recompile
    assert after["hits"] == mid["hits"] + 1    # the second solve was a hit
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))
    np.testing.assert_array_equal(np.asarray(a.S), np.asarray(b.S))
    np.testing.assert_array_equal(np.asarray(a.v), np.asarray(b.v))

    # distinct kernel options (block_size) -> distinct executable key,
    # same numerics; private cache isolates the count assertion.
    private = ExecutableCache()
    c = Estimator(model, method="parallel_kernel",
                  options=KOPTS.replace(block_size=8),
                  cache=private).solve(problem)
    assert private.misses == 1
    assert _max_abs(a.x, c.x) < 1e-10


def test_parallel_kernel_lower_aot(wiener):
    model, ts, y = wiener
    est = Estimator(model, method="parallel_kernel", options=KOPTS)
    problem = Problem.single(model, ts, y)
    compiled = est.lower(problem).compile()
    sol_aot = compiled(ts, y)
    sol = est.solve(problem)
    np.testing.assert_array_equal(np.asarray(sol_aot.x), np.asarray(sol.x))
