"""Streaming fixed-lag estimation service.

``StreamingEngine`` turns the batch :class:`~repro.core.Estimator` into an
online service: clients open tracks, push measurements as they arrive, and
read back MAP estimates that are continuously refined over a sliding
window of the most recent ``lag`` intervals.

Fixed-lag smoothing, exactly
----------------------------

Every window re-solve passes the *filter information at the window's left
edge* -- ``(Solution.S[k], Solution.v[k])`` of the previous solve -- as an
information-form boundary prior (``Problem(..., prior=(S0, v0))``).  For
linear models this makes the chained window solves EXACTLY equal to the
one-shot offline MAP restricted to the window (the information recursion
is the same sums in a different order; tests verify agreement to
~1e-14).  States older than the lag are **evicted**: committed as final
:class:`~repro.core.Solution` segments and never re-solved.  A committed
state is the MAP estimate given all measurements up to ``lag`` intervals
after it -- the classic fixed-lag approximation, exact in the window and
within smoothing-decay of the full MAP behind it (docs/STREAMING.md).

Nonlinear models additionally warm-start each re-solve from the previous
window's trajectory (per-row ``x_init``), so the iterated smoother
re-linearises from an already-converged nominal instead of the prior
mean.

Batching
--------

Due windows (tracks with un-solved pushes) are drained in fixed-size
waves through the same machinery as :class:`TrajectoryEngine`
(:mod:`repro.serving.waves`): FIFO by first-push time, grouped by padded
bucket length, short waves recycle a live row, one compiled executable
per (bucket, batch) reused forever.  Windows across DIFFERENT tracks
batch together -- that is the point of a fixed window size: every track's
window pads to the same few bucket lengths.

Observability: with :mod:`repro.obs` enabled the engine reports the
``stream.*`` taxonomy (pushes, open tracks, per-wave occupancy/padding,
``stream.window_latency_seconds`` push-to-solve latency, eviction
counters) -- see docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core.estimator import Estimator, Problem
from repro.core.padding import bucket_length, slice_solution
from repro.core.sde import LinearSDE, NonlinearSDE
from repro.core.types import Solution

from .waves import (
    WaveItem,
    pack_wave,
    record_wave_metrics,
    robust_default_options,
    take_wave,
)


class _Track:
    """Per-track streaming state (mutated only under the engine lock).

    ``offset`` counts evicted intervals: the live window covers track
    intervals ``[offset, offset + y.shape[0])``.  ``committed_*`` hold the
    evicted history (``offset`` states); ``win_*`` the window estimate of
    the last solve; ``prior`` the information-form boundary at the
    window's left edge (``None`` until the first eviction -- the model
    prior applies).
    """

    __slots__ = ("ts", "y", "offset", "prior", "x_warm", "win_x", "win_S",
                 "win_v", "committed_x", "committed_S", "committed_v",
                 "due_since", "solves", "last_cost")

    def __init__(self, t0: float):
        self.ts = np.asarray([t0], dtype=float)
        self.y: Optional[np.ndarray] = None        # (N, ny) window intervals
        self.offset = 0                            # evicted intervals
        self.prior: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self.x_warm: Optional[np.ndarray] = None   # (N+1, nx) last window x
        self.win_x: Optional[np.ndarray] = None    # last SOLVED window
        self.win_S: Optional[np.ndarray] = None
        self.win_v: Optional[np.ndarray] = None
        self.committed_x: List[np.ndarray] = []
        self.committed_S: List[np.ndarray] = []
        self.committed_v: List[np.ndarray] = []
        self.due_since = 0.0        # perf_counter of the push that made us due
        self.solves = 0
        self.last_cost: Optional[float] = None

    @property
    def intervals(self) -> int:
        """Total intervals pushed so far (committed + window)."""
        return self.offset + (0 if self.y is None else self.y.shape[0])


class StreamingEngine:
    """Multi-track fixed-lag smoother service over one model.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`.
      lag: window length in INTERVALS kept live behind the newest
        measurement; anything older is evicted as committed history after
        the next solve.  Larger lag = closer to the full MAP for the
        committed states, more work per re-solve.
      batch: fixed wave size -- due windows from different tracks are
        solved ``batch`` at a time (compiled once per bucket length).
      method / options / mesh / batch_axis: forwarded to the underlying
        :class:`~repro.core.Estimator` (same surface as
        :class:`TrajectoryEngine`; ``options=None`` = method defaults in
        the robust ``discrete`` element mode, see
        :func:`repro.serving.waves.robust_default_options`).
      diagnostics: forwarded to the Estimator; the streaming default is
        ``False`` (skip cost/step-norm traces -- latency path).

    API: ``open_track(t0) -> id``; ``push(id, ts_new, y_new)`` appends
    measurements (``ts_new`` strictly increasing, after the track's last
    time point); ``step()`` solves one wave of due windows; ``run()``
    drains; ``estimate(id)`` returns the stitched committed + window
    :class:`Solution`; ``window(id)`` / ``committed(id)`` the parts;
    ``close(id)`` finalises and removes the track.

    ``open_track``/``push``/``estimate``/``collect``-style readers are
    thread-safe; drive ``step``/``run`` from ONE solver thread while
    clients push concurrently (pushes landing mid-solve simply mark the
    track due again).
    """

    def __init__(
        self,
        model: Union[LinearSDE, NonlinearSDE],
        *,
        lag: int = 32,
        batch: int = 8,
        method: str = "parallel_rts",
        options=None,
        bucket_sizes: Optional[Sequence[int]] = None,
        mesh=None,
        batch_axis: str = "data",
        diagnostics: bool = False,
    ):
        if lag < 1:
            raise ValueError(f"lag must be >= 1 interval, got {lag}")
        if options is None:
            # serving default: the robust exact-composition mode -- a
            # streaming window grows without bound between solves, so the
            # length-dependent stability of the euler default is exactly
            # the failure mode to avoid (see robust_default_options).
            options = robust_default_options(method)
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.estimator = Estimator(model, method=method, options=options,
                                   mesh=mesh, batch_axis=batch_axis,
                                   diagnostics=diagnostics)
        shard = self.estimator._batch_shard_size(
            self.estimator._resolved_mesh())
        if batch % shard:
            raise ValueError(
                f"batch {batch} not divisible by mesh batch axis size "
                f"{shard}")
        self.model = model
        self.lag = lag
        self.batch = batch
        self.bucket_sizes = bucket_sizes
        self.nonlinear = isinstance(model, NonlinearSDE)

        self._lock = threading.Lock()
        self._tracks: Dict[int, _Track] = {}
        # track id -> insertion order IS the FIFO due order
        self._due: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._next_id = 0
        self.waves = 0
        self.evicted_intervals = 0

    # -- client surface -----------------------------------------------------

    def open_track(self, t0: float = 0.0) -> int:
        """Open a streaming track whose time grid starts at ``t0``;
        returns the track id used by every other call."""
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            self._tracks[tid] = _Track(float(t0))
            n = len(self._tracks)
        if obs.enabled():
            obs.inc("stream.tracks_opened")
            obs.set_gauge("stream.tracks", n)
        return tid

    def push(self, track_id: int, ts_new, y_new) -> None:
        """Append measurements to a track and mark its window due.

        ``ts_new`` (``(K,)``) are the new grid points -- strictly
        increasing and strictly after the track's current last time --
        and ``y_new`` (``(K, ny)``) the measurement at each.
        """
        ts_new = np.asarray(ts_new, dtype=float)
        y_new = np.asarray(y_new)
        if ts_new.ndim != 1 or ts_new.shape[0] < 1:
            raise ValueError(
                f"ts_new must be (K,) with K >= 1, got shape {ts_new.shape}")
        if y_new.ndim != 2 or y_new.shape[0] != ts_new.shape[0]:
            raise ValueError(
                f"y_new must be (K, ny) = ({ts_new.shape[0]}, ny), got "
                f"shape {y_new.shape}")
        if not np.all(np.diff(ts_new) > 0):
            raise ValueError(
                f"ts_new must be strictly increasing; got {ts_new!r}")
        ny = self.model.ny
        if ny is not None and y_new.shape[1] != ny:
            raise ValueError(
                f"y_new has measurement dimension {y_new.shape[1]} but "
                f"the model's R is {ny}x{ny} (ny={ny})")
        with self._lock:
            track = self._get(track_id)
            if ts_new[0] <= track.ts[-1]:
                raise ValueError(
                    f"ts_new must start strictly after the track's last "
                    f"time {track.ts[-1]}; got ts_new[0]={ts_new[0]}")
            if track.y is not None and y_new.shape[1] != track.y.shape[1]:
                raise ValueError(
                    f"y_new has ny={y_new.shape[1]}, track has "
                    f"ny={track.y.shape[1]}")
            track.ts = np.concatenate([track.ts, ts_new])
            track.y = (y_new.copy() if track.y is None
                       else np.concatenate([track.y, y_new]))
            if track_id not in self._due:
                track.due_since = time.perf_counter()
                self._due[track_id] = None
            depth = len(self._due)
        if obs.enabled():
            obs.inc("stream.pushes")
            obs.inc("stream.pushed_intervals", ts_new.shape[0])
            obs.set_gauge("stream.queue_depth", depth)

    def due(self) -> int:
        """Number of tracks with un-solved pushes."""
        return len(self._due)

    def tracks(self) -> List[int]:
        with self._lock:
            return sorted(self._tracks)

    # -- wave processing ----------------------------------------------------

    def step(self) -> int:
        """Solve one wave of due windows; returns windows solved (0 if
        nothing is due).  Snapshots each due track's CURRENT window, so a
        push landing mid-solve marks the track due again for the next
        wave rather than being lost."""
        with self._lock:
            if not self._due:
                return 0
            queue = collections.deque(
                self._snapshot(tid) for tid in self._due)
            wave = take_wave(queue, self.batch)
            for item in wave:
                del self._due[item.key]
            depth = len(self._due)
        with obs.trace_span("stream.step"):
            n_pad = wave[0].n_pad
            ts_b, ys_b, mask_b, xi_b, pr_b = pack_wave(wave, self.batch)
            sol = self.estimator.solve(
                Problem.stacked(self.model, ts_b, ys_b,
                                measurement_mask=mask_b,
                                x_init=xi_b, prior=pr_b))
            with self._lock:
                for row, item in enumerate(wave):
                    self._apply(item, slice_solution(
                        sol, row, item.y.shape[0]))
                self.waves += 1
            if obs.enabled():
                record_wave_metrics("stream", wave, n_pad, self.batch, depth)
        return len(wave)

    def run(self) -> int:
        """Drain every due window; returns total windows solved.  With
        :mod:`repro.obs` enabled sets ``stream.windows_per_sec``."""
        total = 0
        t0 = time.perf_counter()
        with obs.trace_span("stream.run"):
            while self._due:
                total += self.step()
        dt = time.perf_counter() - t0
        if total and dt > 0:
            obs.set_gauge("stream.windows_per_sec", total / dt)
        return total

    # -- estimates ----------------------------------------------------------

    def estimate(self, track_id: int) -> Solution:
        """Stitched committed + window estimate: ``x``/``S``/``v`` over
        every SOLVED time point of the track (``n_solved + 1`` states).

        ``S``/``v`` are the forward-filter information at each point (the
        quantity the window handoff chains on); pushes newer than the
        last solve are not included -- call :meth:`run` first for a
        fully-refreshed estimate.
        """
        with self._lock:
            track = self._get(track_id)
            if track.win_x is None:
                raise ValueError(
                    f"track {track_id} has no estimate yet -- push "
                    "measurements and call step()/run() first")
            return Solution(
                x=np.concatenate(track.committed_x + [track.win_x]),
                S=np.concatenate(track.committed_S + [track.win_S]),
                v=np.concatenate(track.committed_v + [track.win_v]),
                cost=track.last_cost)

    def window(self, track_id: int) -> Solution:
        """The live window's estimate alone (last solve; ``lag + 1`` states
        once the track is past its lag)."""
        with self._lock:
            track = self._get(track_id)
            if track.win_x is None:
                raise ValueError(
                    f"track {track_id} has no estimate yet -- push "
                    "measurements and call step()/run() first")
            return Solution(x=track.win_x, S=track.win_S, v=track.win_v)

    def committed(self, track_id: int) -> Optional[Solution]:
        """The evicted (finalised) history as a Solution segment of
        ``offset`` states, or ``None`` if nothing has been evicted yet.
        Committed states are never re-solved."""
        with self._lock:
            track = self._get(track_id)
            if not track.committed_x:
                return None
            return Solution(x=np.concatenate(track.committed_x),
                            S=np.concatenate(track.committed_S),
                            v=np.concatenate(track.committed_v))

    def close(self, track_id: int) -> Solution:
        """Finalise a track: solve any outstanding pushes, return the full
        stitched estimate, and drop the track's state."""
        self.run()
        final = self.estimate(track_id)
        with self._lock:
            del self._tracks[track_id]
            self._due.pop(track_id, None)
            n = len(self._tracks)
        if obs.enabled():
            obs.inc("stream.tracks_closed")
            obs.set_gauge("stream.tracks", n)
        return final

    # -- internals ----------------------------------------------------------

    def _get(self, track_id: int) -> _Track:
        try:
            return self._tracks[track_id]
        except KeyError:
            raise KeyError(
                f"unknown track id {track_id} (open tracks: "
                f"{sorted(self._tracks)})") from None

    def _snapshot(self, tid: int) -> WaveItem:
        """WaveItem for a due track's current window (caller holds lock).
        Arrays are never mutated in place (pushes re-concatenate), so the
        references stay valid while the solve runs outside the lock."""
        track = self._tracks[tid]
        n_pad = bucket_length(track.y.shape[0], self.estimator.block_size,
                              self.bucket_sizes)
        x_init = None
        if self.nonlinear:
            # uniform warm start across the wave: re-solves continue from
            # the previous window trajectory, fresh windows from the prior
            # mean (= iterated_solve's own default)
            if track.x_warm is not None:
                x_init = track.x_warm
            elif track.prior is None:
                x_init = np.broadcast_to(
                    np.asarray(self.model.m0),
                    (track.y.shape[0] + 1,) + np.shape(self.model.m0))
            else:
                mean = np.linalg.solve(track.prior[0], track.prior[1])
                x_init = np.broadcast_to(
                    mean, (track.y.shape[0] + 1,) + mean.shape)
        return WaveItem(tid, track.ts, track.y, n_pad, track.due_since,
                        x_init=x_init, prior=track.prior)

    def _apply(self, item: WaveItem, sol: Solution) -> None:
        """Fold one window solution back into its track (caller holds
        lock): store the window estimate, evict past the lag, advance the
        boundary prior and warm start."""
        track = self._tracks.get(item.key)
        if track is None:                      # closed mid-solve
            return
        n = item.y.shape[0]                    # window intervals at snapshot
        x = np.asarray(sol.x)
        S = np.asarray(sol.S)
        v = np.asarray(sol.v)
        evict = max(0, n - self.lag)
        if evict:
            track.committed_x.append(x[:evict])
            track.committed_S.append(S[:evict])
            track.committed_v.append(v[:evict])
            track.prior = (S[evict].copy(), v[evict].copy())
            track.ts = track.ts[evict:]
            track.y = track.y[evict:]
            track.offset += evict
            self.evicted_intervals += evict
            if obs.enabled():
                obs.inc("stream.evicted_intervals", evict)
        track.win_x, track.win_S, track.win_v = \
            x[evict:], S[evict:], v[evict:]
        track.x_warm = x[evict:] if self.nonlinear else None
        track.solves += 1
        if sol.cost is not None:
            track.last_cost = float(sol.cost)
