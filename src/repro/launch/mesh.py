"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialisation; the dry-run sets XLA_FLAGS before first import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips.
    Multi-pod: (2, 16, 16) over ("pod", "data", "model") = 512 chips;
    the pod axis folds into data parallelism (gradient reductions cross
    the inter-pod links; see DESIGN.md S5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this process actually has (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
