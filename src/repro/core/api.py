"""Top-level user API for continuous-time MAP trajectory estimation.

    from repro.core import map_estimate
    sol = map_estimate(model, ts, y, method="parallel_rts")

``model`` is a :class:`~repro.core.sde.LinearSDE` or
:class:`~repro.core.sde.NonlinearSDE`; nonlinear models are solved with the
iterated linearisation of section 4.4.  All solvers are jit-friendly pure
functions; batches of measurement records can be handled with ``jax.vmap``
(see examples/).
"""
from __future__ import annotations

from typing import Union

import jax.numpy as jnp

from .nonlinear import iterated_map
from .parallel import parallel_rts, parallel_two_filter
from .sde import LinearSDE, NonlinearSDE, grid_lqt_from_linear
from .sequential import sequential_rts, sequential_two_filter
from .types import MAPSolution

METHODS = (
    "parallel_rts", "parallel_two_filter",
    "sequential_rts", "sequential_two_filter",
)


def map_estimate(
    model: Union[LinearSDE, NonlinearSDE],
    ts: jnp.ndarray,
    y: jnp.ndarray,
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
) -> MAPSolution:
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")

    if isinstance(model, NonlinearSDE):
        return iterated_map(
            model, ts, y, iterations=iterations, method=method, nsub=nsub,
            mode=mode, divergence_correction=divergence_correction)

    grid = grid_lqt_from_linear(model, ts, y)
    if method == "parallel_rts":
        return parallel_rts(grid, nsub, mode)
    if method == "parallel_two_filter":
        return parallel_two_filter(grid, nsub, mode)
    if method == "sequential_rts":
        return sequential_rts(grid, mode)
    return sequential_two_filter(grid, mode)
