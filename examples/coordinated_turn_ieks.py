"""Nonlinear tracking: iterated parallel MAP on the coordinated-turn model.

Reproduces the paper's section 5.2 setup (range-bearing measurements of a
turning target, 5 linearisation iterations).  The per-iteration
Onsager-Machlup cost now comes straight off ``Solution.cost_trace`` --
ONE compiled solve yields the whole Gauss-Newton descent curve of the
continuous-time IEKS with a parallel-in-time inner solver.  A second pass
swaps the Taylor linearisation for derivative-free sigma-point SLR
(``method="sigma_point"``, docs/LINEARIZATION.md) and prints the final
cost gap at the same iteration count.

    PYTHONPATH=src python examples/coordinated_turn_ieks.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.configs.coordinated_turn import CoordinatedTurnConfig
from repro.core import (
    Estimator, IteratedOptions, ParallelOptions, Problem,
    SequentialOptions, SigmaPointOptions, simulate_nonlinear, time_grid,
)

cfg = CoordinatedTurnConfig()
model = cfg.model()
T, n = 128, 10
ts = time_grid(cfg.t0, cfg.tf, T * n)
x_true, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(42))
problem = Problem.single(model, ts, y)

par = Estimator(model, method="parallel_rts",
                options=IteratedOptions(
                    iterations=cfg.iterations,
                    inner=ParallelOptions(nsub=n, mode="discrete")))
sol = par.solve(problem)
rmse = float(jnp.sqrt(jnp.mean((sol.x[:, :2] - x_true[:, :2]) ** 2)))

print("iter | OM cost")
for it, cost in enumerate(sol.cost_trace, start=1):
    print(f"  {it}  | {float(cost):12.2f}")
print(f"final position RMSE: {rmse:.4f}")
assert bool(jnp.all(jnp.diff(sol.cost_trace) <= 1e-3 * jnp.abs(
    sol.cost_trace[:-1]))), "IEKS cost must not increase"

seq = Estimator(model, method="sequential_rts",
                options=IteratedOptions(
                    iterations=cfg.iterations,
                    inner=SequentialOptions(mode="discrete")))
gap = float(jnp.abs(sol.x - seq.solve(problem).x).max())
print(f"parallel vs sequential IEKS max gap: {gap:.2e}")
assert gap < 1e-6

# Sigma-point variant: same iteration count, same parallel inner solver,
# but each pass linearises by statistical linear regression through
# unscented points instead of Jacobians (posterior-linearisation smoother).
sp = Estimator(model, method="sigma_point",
               options=SigmaPointOptions(
                   iterations=cfg.iterations,
                   inner=ParallelOptions(nsub=n, mode="discrete")))
sp_sol = sp.solve(problem)
t_cost, s_cost = float(sol.cost), float(sp_sol.cost)
print(f"final OM cost  taylor={t_cost:.6f}  unscented={s_cost:.6f}  "
      f"gap={s_cost - t_cost:+.2e}")
assert s_cost <= t_cost * (1 + 1e-6), \
    "sigma-point SLR must not end above the Taylor IEKS cost"
print("OK")
