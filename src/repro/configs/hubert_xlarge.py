"""hubert-xlarge: 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504.

Encoder-only audio backbone [arXiv:2106.07447].  The convolutional waveform
frontend is a STUB per the assignment: ``input_specs`` provides precomputed
frame embeddings (B, S, d_model); training is masked-frame prediction over
the 504-unit codebook.  No decode step exists (DESIGN.md S4 skips).
"""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder",
        num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
        d_ff=5120, vocab_size=504, mlp_type="plain", act="gelu",
        causal=False, input_mode="embeddings", mixer="attn", remat_group=8)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="hubert-xlarge-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128)
