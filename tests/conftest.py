"""Shared test configuration.

float64 is enabled globally: the estimation-theory tests need it, and all
model code is dtype-explicit (bf16/f32 literals) so it is unaffected.
NOTE: tests intentionally see the single real CPU device -- only
launch/dryrun.py forces 512 host platform devices (and only in its own
process).  Multi-device tests spawn subprocesses.
"""
import os

# Keep any ambient dry-run flags out of the test process.
os.environ.pop("XLA_FLAGS", None)

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
