from . import mesh, steps
