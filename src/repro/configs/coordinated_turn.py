"""Paper section 5.2: the coordinated-turn model (eqs. 55-58) -- the
nonlinear experiment behind Fig. 2 (5 IEKS iterations)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import NonlinearSDE


@dataclasses.dataclass(frozen=True)
class CoordinatedTurnConfig:
    t0: float = 0.0
    tf: float = 5.0
    sigma_v: float = 5e-4
    sigma_w: float = 0.02
    iterations: int = 5       # paper: 5 linearisation iterations
    nsub: int = 10
    q_jitter: float = 1e-10   # Q = L W L^T is singular in the position rows

    def model(self) -> NonlinearSDE:
        L = (jnp.zeros((5, 3))
             .at[2, 0].set(self.sigma_v)
             .at[3, 1].set(self.sigma_v)
             .at[4, 2].set(self.sigma_w))
        Q = L @ jnp.eye(3) @ L.T + self.q_jitter * jnp.eye(5)

        def f(x, t):
            return jnp.array([x[2], x[3], -x[4] * x[3], x[4] * x[2], 0.0])

        def h(x, t):
            return jnp.array([jnp.sqrt(x[0] ** 2 + x[1] ** 2),
                              jnp.arctan2(x[1], x[0])])

        return NonlinearSDE(
            f=f, h=h, Q=Q, R=jnp.diag(jnp.array([5e-3, 1e-3])),
            m0=jnp.array([5.0, 5.0, 0.0, 0.3, 0.0]),
            P0=jnp.diag(jnp.array([0.01, 0.01, 0.01, 0.01, 0.04])))


def config() -> CoordinatedTurnConfig:
    return CoordinatedTurnConfig()
