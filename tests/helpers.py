"""Shared model fixtures for the estimation tests (paper section 5 models)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import LinearSDE, NonlinearSDE


def wiener_velocity(q: float = 4.0, r: float = 1e-2, p0: float = 1.0,
                    q_jitter: float = 1e-6) -> LinearSDE:
    """Paper eq. (52)-(54).  ``p0`` defaults to 1.0 in tests (the paper's
    1e-2 makes the explicit-Euler Riccati stiff unless dt < 2.5e-3, see
    DESIGN.md S6); benchmarks use the paper's exact 1e-2.  ``q_jitter``
    regularises the singular Q = L W L^T only where an inverse of Q is
    required (QP oracle / OM cost); the solvers never invert Q."""
    F = jnp.block([[jnp.zeros((2, 2)), jnp.eye(2)], [jnp.zeros((2, 4))]])
    H = jnp.concatenate([jnp.eye(2), jnp.zeros((2, 2))], axis=1)
    L = jnp.concatenate([jnp.zeros((2, 2)), jnp.eye(2)], axis=0)
    Q = L @ (q * jnp.eye(2)) @ L.T + q_jitter * jnp.eye(4)
    return LinearSDE(
        F=F, c=jnp.zeros(4), H=H, r=jnp.zeros(2), Q=Q,
        R=r * jnp.eye(2),
        m0=jnp.array([5.0, 5.0, 0.0, 0.0]), P0=p0 * jnp.eye(4))


def random_ltv(key, nx: int = 3, ny: int = 2) -> LinearSDE:
    """A well-conditioned random linear time-varying model."""
    ks = jax.random.split(key, 6)
    A = jax.random.normal(ks[0], (nx, nx)) * 0.3
    B = jax.random.normal(ks[1], (nx, nx)) * 0.2
    Hm = jax.random.normal(ks[2], (ny, nx))
    Lq = jax.random.normal(ks[3], (nx, nx)) * 0.3

    def F(t):
        return A + B * jnp.sin(t)

    def c(t):
        return jnp.array([0.1, -0.2, 0.05])[:nx] * jnp.cos(t)

    Q = Lq @ Lq.T + 0.5 * jnp.eye(nx)
    return LinearSDE(
        F=F, c=c, H=Hm, r=0.1 * jnp.ones(ny), Q=Q, R=0.5 * jnp.eye(ny),
        m0=jax.random.normal(ks[4], (nx,)),
        P0=jnp.eye(nx) * 0.8)


def coordinated_turn() -> NonlinearSDE:
    """Paper eqs. (55)-(58) exactly."""
    sv, sw = 5e-4, 0.02
    L = jnp.zeros((5, 3)).at[2, 0].set(sv).at[3, 1].set(sv).at[4, 2].set(sw)
    Q = L @ jnp.eye(3) @ L.T + 1e-10 * jnp.eye(5)

    def f(x, t):
        return jnp.array([x[2], x[3], -x[4] * x[3], x[4] * x[2], 0.0])

    def h(x, t):
        return jnp.array([jnp.sqrt(x[0] ** 2 + x[1] ** 2),
                          jnp.arctan2(x[1], x[0])])

    return NonlinearSDE(
        f=f, h=h, Q=Q, R=jnp.diag(jnp.array([5e-3, 1e-3])),
        m0=jnp.array([5.0, 5.0, 0.0, 0.3, 0.0]),
        P0=jnp.diag(jnp.array([0.01, 0.01, 0.01, 0.01, 0.04])))
