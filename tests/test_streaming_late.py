"""Late / out-of-order measurement handling and adaptive lag in
``StreamingEngine``.

The exactness contract under rewind: an in-window late push merges in
time order and the re-solved window equals the offline MAP restricted to
the window ON THE SAME DATA -- the boundary prior only summarises evicted
history, so merging inside the window costs nothing (linear: ~1e-14
observed, 1e-9 demanded; nonlinear including ``method="sigma_point"``: to
Gauss-Newton tolerance).  Shuffled-then-merged pushes must equal in-order
pushes; too-late data is counted and dropped unless ``reorder_slack``
keeps the horizon back far enough; duplicates follow the engine policy.
Adaptive lag must converge to (within +-2 intervals of) the smallest
fixed lag meeting the same committed-error target.
"""
import jax
import numpy as np
import pytest

from helpers import coordinated_turn, wiener_velocity
from repro import obs
from repro.core import (
    Estimator, IteratedOptions, ParallelOptions, Problem, simulate_linear,
    simulate_nonlinear, time_grid,
)
from repro.serving import StreamingEngine
from repro.serving.waves import insert_warm_states, merge_measurements

NSUB = 5
OPTIONS = ParallelOptions(nsub=NSUB, mode="discrete")


def _linear_data(N, seed=0, T=None):
    model = wiener_velocity()
    ts = time_grid(0.0, (N / 10.0) if T is None else T, N)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(seed))
    return model, np.asarray(ts), np.asarray(y)


def _offline(model, ts, y, options=OPTIONS):
    return np.asarray(
        Estimator(model, options=options).solve(
            Problem.single(model, ts, y)).x)


# -- merge helpers (repro.serving.waves) ----------------------------------


def test_merge_measurements_classification():
    ts = np.array([0.0, 1.0, 2.0, 3.0])
    y = np.array([[1.0], [2.0], [3.0]])
    res = merge_measurements(
        ts, y, np.array([-1.0, 0.0, 1.5, 4.0]),
        np.array([[9.0], [9.0], [1.5], [4.0]]))
    assert (res.dropped_late, res.merged, res.appended, res.replaced) == \
        (2, 1, 1, 0)
    np.testing.assert_array_equal(res.ts, [0.0, 1.0, 1.5, 2.0, 3.0, 4.0])
    np.testing.assert_array_equal(
        res.y, [[1.0], [1.5], [2.0], [3.0], [4.0]])
    assert res.changed
    # inputs were not mutated (snapshots taken before the merge stay valid)
    np.testing.assert_array_equal(ts, [0.0, 1.0, 2.0, 3.0])
    np.testing.assert_array_equal(y, [[1.0], [2.0], [3.0]])


def test_merge_measurements_duplicate_policies():
    ts = np.array([0.0, 1.0, 2.0])
    y = np.array([[1.0], [2.0]])
    dup_t, dup_y = np.array([1.0]), np.array([[7.0]])
    with pytest.raises(ValueError, match="duplicate"):
        merge_measurements(ts, y, dup_t, dup_y, duplicate="error")
    rep = merge_measurements(ts, y, dup_t, dup_y, duplicate="replace")
    assert rep.replaced == 1 and rep.changed
    np.testing.assert_array_equal(rep.y, [[7.0], [2.0]])
    np.testing.assert_array_equal(y, [[1.0], [2.0]])    # copy, not in place
    drop = merge_measurements(ts, y, dup_t, dup_y, duplicate="drop")
    assert drop.dropped_duplicates == 1 and not drop.changed
    np.testing.assert_array_equal(drop.y, y)
    with pytest.raises(ValueError, match="duplicate policy"):
        merge_measurements(ts, y, dup_t, dup_y, duplicate="overwrite")


def test_merge_measurements_fresh_track():
    res = merge_measurements(
        np.array([0.0]), None, np.array([1.0, 2.0]),
        np.array([[1.0], [2.0]]))
    assert res.appended == 2 and res.merged == 0
    np.testing.assert_array_equal(res.y, [[1.0], [2.0]])


def test_insert_warm_states_alignment():
    xw = np.array([[0.0], [1.0], [2.0]])
    out = insert_warm_states(xw, np.array([1, 2]))
    np.testing.assert_array_equal(out, [[0.0], [0.0], [1.0], [1.0], [2.0]])
    # positions past the warm trajectory are ignored (padding covers them)
    np.testing.assert_array_equal(insert_warm_states(xw, np.array([5])), xw)


# -- window-rewind exactness ----------------------------------------------


def test_late_in_window_push_matches_offline_linear():
    """Hold back interior measurements of the final window, solve, push
    them late: the re-solved window must equal the offline MAP on the
    COMPLETE data restricted to the window (1e-9 demanded, ~1e-14
    observed) -- the boundary prior is untouched by an in-window merge."""
    model, ts, y = _linear_data(40)
    ref = _offline(model, ts, y)
    scale = np.max(np.abs(ref))
    lag = 15
    eng = StreamingEngine(model, lag=lag, batch=4, options=OPTIONS)
    tid = eng.open_track(ts[0])
    hold = [30, 33, 35]                      # y indices, inside final window
    mask = np.ones(40, bool)
    mask[hold] = False
    eng.push(tid, ts[1:][mask], y[mask])
    eng.run()
    summary = eng.push(tid, ts[1:][~mask], y[~mask])
    assert summary == {"appended": 0, "merged": 3, "replaced": 0,
                       "dropped_late": 0, "dropped_duplicates": 0}
    eng.run()
    full = np.asarray(eng.estimate(tid).x)
    assert full.shape == ref.shape
    np.testing.assert_allclose(
        full[-lag - 1:], ref[-lag - 1:], rtol=0, atol=1e-9 * scale)


@pytest.mark.parametrize("seed", [0, 1])
def test_shuffled_pushes_equal_in_order(seed):
    """Property: pushing single-interval pieces in ANY order (with
    periodic re-solves between pieces) yields the same final estimate as
    pushing them in time order -- merge + rewind is order-invariant while
    nothing is evicted."""
    model, ts, y = _linear_data(20, seed=3)
    order = np.random.default_rng(seed).permutation(20)
    eng_in = StreamingEngine(model, lag=30, batch=2, options=OPTIONS)
    eng_sh = StreamingEngine(model, lag=30, batch=2, options=OPTIONS)
    t_in, t_sh = eng_in.open_track(ts[0]), eng_sh.open_track(ts[0])
    for n, i in enumerate(range(20)):
        eng_in.push(t_in, ts[i + 1:i + 2], y[i:i + 1])
        j = order[i]
        eng_sh.push(t_sh, ts[j + 1:j + 2], y[j:j + 1])
        if n % 6 == 5:                       # interleave re-solves
            eng_in.run()
            eng_sh.run()
    a = np.asarray(eng_in.estimate(t_in).x)
    b = np.asarray(eng_sh.estimate(t_sh).x)
    scale = np.max(np.abs(a))
    np.testing.assert_allclose(b, a, rtol=0, atol=1e-9 * scale)
    np.testing.assert_allclose(a, _offline(model, ts, y), rtol=0,
                               atol=1e-9 * scale)


def test_late_in_window_push_matches_offline_nonlinear():
    """Nonlinear rewind: a late in-window push re-solves the warm-started
    iterated window to Gauss-Newton tolerance of the offline iterated
    solve on the complete data."""
    model = coordinated_turn()
    N = 30
    ts = time_grid(0.0, 3.0, N)
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(2))
    ts, y = np.asarray(ts), np.asarray(y)
    opts = IteratedOptions(iterations=12,
                           inner=ParallelOptions(nsub=NSUB, mode="discrete"))
    ref = _offline(model, ts, y, options=opts)
    eng = StreamingEngine(model, lag=64, batch=2, options=opts)
    tid = eng.open_track(ts[0])
    hold = [20, 24]
    mask = np.ones(N, bool)
    mask[hold] = False
    eng.push(tid, ts[1:][mask], y[mask])
    eng.run()
    assert eng.push(tid, ts[1:][~mask], y[~mask])["merged"] == 2
    eng.run()
    full = np.asarray(eng.estimate(tid).x)
    scale = np.max(np.abs(ref))
    np.testing.assert_allclose(full, ref, rtol=0, atol=1e-6 * scale)


def test_late_in_window_push_matches_offline_sigma_point():
    """The rewind path composes with method="sigma_point" (SLR inner
    linearisation): late-merged windows agree with the offline
    sigma-point solve on the same data."""
    model = coordinated_turn()
    N = 20                                   # multiple of the default nsub
    ts = time_grid(0.0, 2.0, N)
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(5))
    ts, y = np.asarray(ts), np.asarray(y)
    # same options for reference and engine (the engines default to the
    # discrete inner mode, a different discretisation from the
    # Estimator's euler default); extra iterations for a converged ref
    from repro.serving.waves import robust_default_options
    opts = robust_default_options("sigma_point").replace(iterations=12)
    est = Estimator(model, method="sigma_point", options=opts)
    ref = np.asarray(est.solve(Problem.single(model, ts, y)).x)
    eng = StreamingEngine(model, lag=64, batch=2, method="sigma_point",
                          options=opts)
    tid = eng.open_track(ts[0])
    mask = np.ones(N, bool)
    mask[[12, 15]] = False
    eng.push(tid, ts[1:][mask], y[mask])
    eng.run()
    assert eng.push(tid, ts[1:][~mask], y[~mask])["merged"] == 2
    eng.run()
    full = np.asarray(eng.estimate(tid).x)
    scale = np.max(np.abs(ref))
    np.testing.assert_allclose(full, ref, rtol=0, atol=1e-5 * scale)


# -- mid-solve races -------------------------------------------------------


def test_mid_solve_merge_into_evicted_region_is_not_lost():
    """Regression: a push merging into the about-to-be-evicted region
    WHILE a solve was in flight used to corrupt the track -- _apply
    sliced ts/y by snapshot index, so the insertion shifted the window
    boundary off the stored prior and silently discarded the merged
    measurement.  The eviction is now deferred to the re-solve the merge
    itself queued, and the final estimate matches the offline MAP on the
    complete data."""
    model, ts, y = _linear_data(40)
    lag = 8
    eng = StreamingEngine(model, lag=lag, batch=1, options=OPTIONS)
    tid = eng.open_track(ts[0])
    hold = 28                                # y index; time ts[29]
    mask = np.ones(40, bool)
    mask[hold] = False
    eng.push(tid, ts[1:33][mask[:32]], y[:32][mask[:32]])
    eng.run()                                # horizon ts[23] < ts[29]
    eng.push(tid, ts[33:], y[32:])           # next solve evicts past ts[29]
    real_solve = eng.estimator.solve
    raced = []

    def racing_solve(problem):
        sol = real_solve(problem)
        if not raced:                        # once, while "in flight"
            raced.append(eng.push(tid, ts[hold + 1:hold + 2],
                                  y[hold:hold + 1]))
        return sol

    eng.estimator.solve = racing_solve
    try:
        eng.step()                           # snapshot predates the merge
    finally:
        eng.estimator.solve = real_solve
    assert raced and raced[0]["merged"] == 1
    assert eng.due() == 1                    # the merge queued a re-solve
    eng.run()
    ref = _offline(model, ts, y)
    full = np.asarray(eng.estimate(tid).x)
    assert full.shape == ref.shape           # the merged point survived
    scale = np.max(np.abs(ref))
    np.testing.assert_allclose(full[-lag - 1:], ref[-lag - 1:],
                               rtol=0, atol=1e-9 * scale)
    track = eng._tracks[tid]
    assert track.y.shape[0] == lag           # eviction caught up
    assert track.ts[0] == ts[40 - lag]       # boundary matches the prior


def test_evict_residual_matches_rows_by_timestamp():
    """Regression: the adaptive-lag signal compared evicted states to
    the previous window POSITIONALLY, so a measurement merged between
    the two solves shifted rows and the residual differenced states at
    DIFFERENT time points.  Rows are now matched by timestamp and
    just-merged points (no previous estimate) are skipped."""
    model, ts, y = _linear_data(40)
    eng = StreamingEngine(model, lag=8, batch=1, options=OPTIONS)
    tid = eng.open_track(ts[0])
    hold = 16                                # time ts[17]
    mask = np.ones(24, bool)
    mask[hold] = False
    eng.push(tid, ts[1:25][mask], y[:24][mask])
    eng.run()                                # window grid ts15,ts16,ts18..ts24
    prev_ts = eng._tracks[tid].ts.copy()
    prev_x = np.asarray(eng.window(tid).x)
    eng.push(tid, ts[hold + 1:hold + 2], y[hold:hold + 1])  # merge at pos 2
    eng.push(tid, ts[25:29], y[24:28])
    eng.run()                                # evicts ts15..ts19 incl. merged
    committed = eng.committed(tid)
    assert committed.x.shape[0] == 20        # 15 + 5 this round
    prev_index = {t: i for i, t in enumerate(prev_ts)}
    expected = max(
        float(np.max(np.abs(committed.x[15 + i] - prev_x[prev_index[t]])))
        for i, t in enumerate(ts[15:20]) if t in prev_index)
    assert ts[17] not in prev_index          # merged point has no previous
    assert eng._tracks[tid].last_evict_delta == pytest.approx(
        expected, rel=1e-12)


# -- committed-horizon drops and the reorder buffer ------------------------


def test_too_late_push_is_dropped_and_counted():
    model, ts, y = _linear_data(30)
    was = obs.enabled()
    obs.reset()
    obs.enable()
    try:
        eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
        tid = eng.open_track(ts[0])
        eng.push(tid, ts[1:], y)
        eng.run()                            # evicts 22: horizon = ts[22]
        before = np.asarray(eng.estimate(tid).x)
        summary = eng.push(tid, ts[3:5], y[2:4])
        assert summary["dropped_late"] == 2
        assert eng.due() == 0                # nothing merged -> no re-solve
        np.testing.assert_array_equal(np.asarray(eng.estimate(tid).x),
                                      before)
        snap = obs.snapshot()
        assert snap["counters"]["stream.late_drops"] == 2
        assert "stream.late_merges" not in snap["counters"]
    finally:
        obs.reset()
        (obs.enable if was else obs.disable)()


def test_reorder_slack_delays_eviction_so_near_late_data_merges():
    """With reorder_slack=s the window keeps lag+s intervals live, so
    measurements up to s intervals behind the lag horizon still merge --
    and the merged estimate equals the offline MAP on the window."""
    model, ts, y = _linear_data(40)
    lag, slack = 8, 6
    # y[20] (time ts[21]) is held back one round.  After the first solve
    # the slack-less horizon is ts[24] (31 intervals - lag 8 evicted 23),
    # so the held point is too late without slack but lands inside the
    # lag+slack window when eviction is delayed by 6 intervals.
    hold = 20
    mask = np.ones(40, bool)
    mask[hold] = False

    def run_engine(s):
        eng = StreamingEngine(model, lag=lag, batch=2, options=OPTIONS,
                              reorder_slack=s)
        tid = eng.open_track(ts[0])
        eng.push(tid, ts[1:33][mask[:32]], y[:32][mask[:32]])
        eng.run()
        summary = eng.push(tid, ts[hold + 1:hold + 2], y[hold:hold + 1])
        eng.push(tid, ts[33:], y[32:])
        eng.run()
        return eng, tid, summary

    # without slack the held measurement is behind the committed horizon
    eng0, t0, s0 = run_engine(0)
    assert s0 == {"appended": 0, "merged": 0, "replaced": 0,
                  "dropped_late": 1, "dropped_duplicates": 0}
    # with slack the horizon sits further back and the merge succeeds
    eng1, t1, s1 = run_engine(slack)
    assert s1["merged"] == 1 and s1["dropped_late"] == 0
    track = eng1._tracks[t1]
    assert track.y.shape[0] == lag + slack   # eviction delayed by slack
    ref = _offline(model, ts, y)
    scale = np.max(np.abs(ref))
    got = np.asarray(eng1.estimate(t1).x)
    np.testing.assert_allclose(got[-(lag + slack) - 1:],
                               ref[-(lag + slack) - 1:],
                               rtol=0, atol=1e-9 * scale)
    # the slack-less run is missing the held measurement (and its grid
    # point) for good: its estimate differs from the complete-data MAP
    got0 = np.asarray(eng0.estimate(t0).x)
    assert got0.shape[0] == 40               # ts[hold + 1] never made it
    assert np.max(np.abs(got0 - np.delete(ref, hold + 1, axis=0))) \
        > 1e-6 * scale


def test_duplicate_policies_through_the_engine():
    model, ts, y = _linear_data(10)
    # replace: the re-sent value wins and the window is re-solved with it
    y2 = y.copy()
    y2[5] += 2.5
    eng = StreamingEngine(model, lag=20, batch=2, options=OPTIONS,
                          duplicate_policy="replace")
    tid = eng.open_track(ts[0])
    eng.push(tid, ts[1:], y)
    eng.run()
    summary = eng.push(tid, ts[6:7], y2[5:6])
    assert summary["replaced"] == 1 and eng.due() == 1
    eng.run()
    np.testing.assert_allclose(
        np.asarray(eng.estimate(tid).x), _offline(model, ts, y2),
        rtol=0, atol=1e-9 * np.max(np.abs(y2)))
    # drop: the original value stays, nothing becomes due
    eng_d = StreamingEngine(model, lag=20, batch=2, options=OPTIONS,
                            duplicate_policy="drop")
    td = eng_d.open_track(ts[0])
    eng_d.push(td, ts[1:], y)
    eng_d.run()
    summary = eng_d.push(td, ts[6:7], y2[5:6])
    assert summary["dropped_duplicates"] == 1 and eng_d.due() == 0
    np.testing.assert_allclose(
        np.asarray(eng_d.estimate(td).x), _offline(model, ts, y),
        rtol=0, atol=1e-9 * np.max(np.abs(y)))


def test_late_obs_taxonomy():
    model, ts, y = _linear_data(30)
    was = obs.enabled()
    obs.reset()
    obs.enable()
    try:
        eng = StreamingEngine(model, lag=10, batch=2, options=OPTIONS,
                              duplicate_policy="drop")
        tid = eng.open_track(ts[0])
        mask = np.ones(30, bool)
        mask[[24, 27]] = False
        eng.push(tid, ts[1:][mask], y[mask])
        eng.run()
        eng.push(tid, ts[1:][~mask], y[~mask])   # in-window late
        eng.push(tid, ts[26:27], y[25:26])       # duplicate -> dropped
        eng.push(tid, ts[2:3], y[1:2])           # behind horizon -> dropped
        eng.run()
        c = obs.snapshot()["counters"]
        assert c["stream.late_merges"] == 2
        assert c["stream.duplicates_dropped"] == 1
        assert c["stream.late_drops"] == 1
        # accepted intervals only (28 appended + 2 merged): the dropped
        # duplicate and the behind-horizon point are NOT counted as
        # pushed, they have their own counters above
        assert c["stream.pushed_intervals"] == 30
    finally:
        obs.reset()
        (obs.enable if was else obs.disable)()


# -- adaptive lag ----------------------------------------------------------


def test_adaptive_lag_grows_shrinks_and_respects_bounds():
    """Unit-level control law: an unreachably tight target grows the lag
    to lag_max; a trivially loose target shrinks it to lag_min; every
    change is counted."""
    model, ts, y = _linear_data(60)
    was = obs.enabled()
    obs.reset()
    obs.enable()
    try:
        tight = StreamingEngine(model, lag=4, batch=1, options=OPTIONS,
                                committed_error_target=1e-12,
                                lag_min=2, lag_max=12)
        tid = tight.open_track(ts[0])
        for i in range(0, 60, 4):
            tight.push(tid, ts[i + 1:i + 5], y[i:i + 4])
            tight.run()
        assert tight.lag == 12
        assert tight.lag_adjustments >= 8
        snap = obs.snapshot()
        assert snap["counters"]["stream.lag_adjustments"] == \
            tight.lag_adjustments
        assert snap["gauges"]["stream.lag"] == 12
        assert "stream.evict_delta" in snap["histograms"]

        loose = StreamingEngine(model, lag=10, batch=1, options=OPTIONS,
                                committed_error_target=1e6,
                                lag_min=3, lag_max=12)
        tid = loose.open_track(ts[0])
        for i in range(0, 60, 4):
            loose.push(tid, ts[i + 1:i + 5], y[i:i + 4])
            loose.run()
        assert loose.lag == 3
    finally:
        obs.reset()
        (obs.enable if was else obs.disable)()


def _fixed_lag_residual_curve(model, ts, y, chunk, lags):
    """The committed-error proxy the engine steers on, computed offline:
    for each committed point k and lag L, how much the MAP estimate of k
    still changes between seeing data up to k+L-chunk and up to k+L --
    exactly the engine's eviction residual (windows are exact).  Returns
    {L: max residual over steady-state k}."""
    N = y.shape[0]
    ends = list(range(chunk, N + 1, chunk))
    est = Estimator(model, options=OPTIONS)
    sols = est.solve(Problem.ragged(
        model, [(ts[:m + 1], y[:m]) for m in ends]))
    xs = {m: np.asarray(s.x)[:m + 1] for m, s in zip(ends, sols)}
    curve = {}
    for lag in lags:
        deltas = []
        for m_prev, m in zip(ends[:-1], ends[1:]):
            # states evicted when the window end reaches m
            for k in range(max(0, m_prev - lag), m - lag):
                if 0 <= k <= m_prev:
                    deltas.append(np.max(np.abs(xs[m][k] - xs[m_prev][k])))
        # steady state: ignore the start-up transient (first quarter)
        curve[lag] = float(np.max(deltas[len(deltas) // 4:]))
    return curve


def test_adaptive_lag_converges_to_smallest_sufficient_fixed_lag():
    """Acceptance: the adaptive lag must settle within +-2 intervals of
    the smallest FIXED lag whose committed-error (eviction residual)
    meets the same target on the same workload -- approached from below
    AND from above."""
    model, ts, y = _linear_data(120, seed=4)
    chunk = 4
    lags = range(3, 25)
    curve = _fixed_lag_residual_curve(model, ts, y, chunk, lags)
    # pick a target the curve actually crosses mid-range
    target = float(np.sqrt(curve[8] * curve[16]))
    l_star = min(L for L in lags if curve[L] <= target)
    assert 4 < l_star < 22, f"degenerate workload: L*={l_star}"

    def final_lag(start):
        eng = StreamingEngine(model, lag=start, batch=1, options=OPTIONS,
                              committed_error_target=target,
                              lag_min=2, lag_max=40)
        tid = eng.open_track(ts[0])
        for i in range(0, 120, chunk):
            eng.push(tid, ts[i + 1:i + 1 + chunk], y[i:i + chunk])
            eng.run()
        return eng.lag

    from_below, from_above = final_lag(3), final_lag(24)
    assert abs(from_below - l_star) <= 2, \
        f"grew to {from_below}, smallest sufficient fixed lag {l_star}"
    assert abs(from_above - l_star) <= 2, \
        f"shrank to {from_above}, smallest sufficient fixed lag {l_star}"


def test_adaptive_lag_estimates_stay_exact():
    """Lag adjustments change WHERE the window ends, never its content:
    the stitched estimate still matches the offline MAP over the final
    window, and committed states match the fixed-lag invariant."""
    model, ts, y = _linear_data(80, seed=6)
    eng = StreamingEngine(model, lag=6, batch=2, options=OPTIONS,
                          committed_error_target=1e-3, lag_min=2,
                          lag_max=30)
    tid = eng.open_track(ts[0])
    for i in range(0, 80, 5):
        eng.push(tid, ts[i + 1:i + 6], y[i:i + 5])
        eng.run()
    ref = _offline(model, ts, y)
    scale = np.max(np.abs(ref))
    full = np.asarray(eng.estimate(tid).x)
    assert full.shape == ref.shape
    win = eng.window(tid).x.shape[0]
    np.testing.assert_allclose(full[-win:], ref[-win:], rtol=0,
                               atol=1e-9 * scale)
    # committed error is bounded by the decay the target asked for (the
    # proxy tracks the true fixed-lag error to a small constant)
    assert np.max(np.abs(full - ref)) / scale < 0.05
