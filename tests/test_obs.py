"""The ``repro.obs`` telemetry spine: registry semantics (thread safety,
disabled no-ops, tracer safety), estimator/engine instrumentation, and the
guarantee that observability never changes numerics.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import coordinated_turn, wiener_velocity
from repro import obs
from repro.core import (
    Estimator,
    IteratedOptions,
    ParallelOptions,
    Problem,
    SequentialOptions,
    cache_stats,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)
from repro.serving import TrajectoryEngine

NSUB = 5


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts disabled + empty and leaves no obs state behind
    (the suite's other tests must keep running on the uninstrumented
    path)."""
    was = obs.enabled()
    obs.disable()
    obs.reset()
    yield
    obs.reset()
    (obs.enable if was else obs.disable)()


def _linear_problem(T=4 * NSUB):
    model = wiener_velocity()
    ts = time_grid(0.0, 1.0, T)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    return model, ts, y


# -- registry semantics -----------------------------------------------------


def test_counter_gauge_histogram_basics():
    obs.enable()
    obs.inc("a.count")
    obs.inc("a.count", 4)
    obs.set_gauge("a.depth", 3)
    obs.set_gauge("a.depth", 7.5)          # last write wins
    for v in (0.001, 0.01, 0.01, 0.1):
        obs.record("a.lat", v)
    snap = obs.snapshot()
    assert snap["enabled"] is True
    assert snap["counters"]["a.count"] == 5
    assert snap["gauges"]["a.depth"] == 7.5
    h = snap["histograms"]["a.lat"]
    assert h["count"] == 4
    assert h["min"] == pytest.approx(0.001)
    assert h["max"] == pytest.approx(0.1)
    assert h["sum"] == pytest.approx(0.121)
    assert snap["dropped_records"] == 0


def test_histogram_percentiles_bucket_accurate():
    obs.enable()
    vals = [i / 1000.0 for i in range(1, 1001)]      # 1ms .. 1s uniform
    for v in vals:
        obs.record("h", v)
    h = obs.histogram("h")
    # geometric buckets are ~2.15x wide; the interpolated estimate must
    # land within one bucket of the true quantile and inside [min, max]
    for q, true in ((0.5, 0.5), (0.9, 0.9), (0.99, 0.99)):
        est = h.percentile(q)
        assert vals[0] <= est <= vals[-1]
        assert true / 2.2 <= est <= true * 2.2, (q, est)
    assert h.percentile(1.0) == pytest.approx(1.0)


def test_exact_counts_under_threads():
    obs.enable()
    threads = [
        threading.Thread(target=lambda: [
            (obs.inc("t.count"), obs.record("t.hist", 0.01))
            for _ in range(1000)])
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert obs.counter("t.count").value == 8000
    assert obs.histogram("t.hist").count == 8000


def test_disabled_is_a_noop_that_allocates_nothing():
    assert not obs.enabled()
    obs.inc("x")
    obs.set_gauge("y", 1.0)
    obs.record("z", 0.5)
    with obs.trace_span("w"):
        pass
    assert obs.REGISTRY.is_empty()
    assert obs.span_trees() == []
    snap = obs.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}


def test_tracer_values_dropped_never_captured():
    obs.enable()

    @jax.jit
    def f(x):
        obs.record("traced.value", x)        # abstract tracer: must drop
        obs.set_gauge("traced.gauge", x)
        return x * 2.0

    out = f(jnp.asarray(3.0))
    assert float(out) == 6.0                 # trace unbroken
    snap = obs.snapshot()
    assert "traced.value" not in snap["histograms"]
    assert "traced.gauge" not in snap["gauges"]
    assert snap["dropped_records"] >= 2


# -- estimator instrumentation ----------------------------------------------


def test_solve_bit_exact_with_obs_on_and_off():
    model, ts, y = _linear_problem()
    est = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=NSUB))
    problem = Problem.single(model, ts, y)
    sol_off = est.solve(problem)
    obs.enable()
    sol_on = est.solve(problem)
    np.testing.assert_array_equal(np.asarray(sol_off.x), np.asarray(sol_on.x))
    np.testing.assert_array_equal(np.asarray(sol_off.cov),
                                  np.asarray(sol_on.cov))
    assert obs.snapshot()["dropped_records"] == 0


def test_solve_phases_and_cache_metrics():
    obs.enable()
    model, ts, y = _linear_problem()
    est = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=NSUB))
    before = cache_stats()
    est.solve(Problem.single(model, ts, y))      # fresh: compiles
    est.solve(Problem.single(model, ts, y))      # cached
    after = cache_stats()
    assert after["misses"] == before["misses"] + 1
    assert after["hits"] >= before["hits"] + 1
    assert set(after) == {"size", "hits", "misses", "evictions"}
    snap = obs.snapshot()
    assert snap["counters"]["cache.misses"] >= 1
    assert snap["counters"]["cache.hits"] >= 1
    assert snap["counters"]["estimator.solves"] == 2
    assert snap["histograms"]["cache.compile_seconds"]["count"] == 1
    h = snap["histograms"]
    assert h["span.estimator.solve"]["count"] == 2
    assert h["span.estimator.solve.prepare"]["count"] == 2
    assert h["span.estimator.solve.compile"]["count"] == 1
    assert h["span.estimator.solve.execute"]["count"] == 1
    # compile span covers the first-run compile: must dominate execute
    assert (h["span.estimator.solve.compile"]["max"]
            > h["span.estimator.solve.execute"]["min"])


def test_nonlinear_iteration_metrics_and_step_norms():
    obs.enable()
    model = coordinated_turn()
    ts = time_grid(0.0, 1.0, 4 * NSUB)
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(0))
    est = Estimator(model, method="parallel_rts",
                    options=IteratedOptions(
                        inner=ParallelOptions(nsub=NSUB), iterations=5))
    sol = est.solve(Problem.single(model, ts, y))
    assert sol.step_norms is not None
    steps = np.asarray(sol.step_norms)
    assert steps.shape == (5,)
    assert steps[-1] < steps[0]          # Gauss-Newton converging
    snap = obs.snapshot()
    assert snap["gauges"]["nonlinear.iterations"] == 5
    assert snap["histograms"]["nonlinear.final_step_norm"]["count"] == 1
    assert snap["histograms"]["nonlinear.cost_decrease"]["count"] == 1


def test_diagnostics_false_keeps_hot_path_silent():
    obs.enable()
    model, ts, y = _linear_problem()
    est = Estimator(model, method="sequential_rts",
                    options=SequentialOptions(), diagnostics=False)
    sol = est.solve(Problem.single(model, ts, y))
    assert sol.cost is None                  # diagnostics skipped
    snap = obs.snapshot()
    assert "estimator.solves" not in snap["counters"]
    # the fast path allocates NO obs instruments: the only registry
    # entries are the executable cache's own counters
    assert snap["histograms"] == {} and snap["gauges"] == {}
    assert all(k.startswith("cache.") for k in snap["counters"])
    assert obs.span_trees() == []


def test_ragged_solve_reports_padding_metrics():
    obs.enable()
    model = wiener_velocity()
    rng = np.random.default_rng(0)
    records = []
    for n in (7, 12, 18, 25):
        ts = np.linspace(0.0, n / 32.0, n + 1)
        records.append((ts, rng.standard_normal((n, 2))))
    est = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=NSUB))
    sols = est.solve(Problem.ragged(model, records))
    assert len(sols) == 4
    snap = obs.snapshot()
    assert snap["counters"]["padding.records"] == 4
    assert snap["counters"]["padding.real_intervals"] == 7 + 12 + 18 + 25
    assert (snap["counters"]["padding.solved_intervals"]
            >= snap["counters"]["padding.real_intervals"])
    assert 0.0 <= snap["gauges"]["padding.waste"] < 1.0


# -- engine instrumentation -------------------------------------------------


def _engine_records(lengths, rng):
    out = []
    for n in lengths:
        ts = np.linspace(0.0, n / 32.0, n + 1)
        out.append((ts, rng.standard_normal((n, 2))))
    return out


def test_engine_wave_and_latency_metrics():
    obs.enable()
    model = wiener_velocity()
    engine = TrajectoryEngine(model, batch=4, method="parallel_rts",
                              options=ParallelOptions(nsub=NSUB))
    recs = _engine_records([7, 12, 9, 14, 8, 11], np.random.default_rng(0))
    sols = engine.estimate(recs)
    assert len(sols) == 6
    snap = obs.snapshot()
    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
    assert c["engine.submitted"] == 6
    assert c["engine.completed"] == 6
    assert c["engine.waves"] == engine.waves
    assert c["engine.real_intervals"] == 7 + 12 + 9 + 14 + 8 + 11
    assert c["engine.padded_intervals"] >= c["engine.real_intervals"]
    assert 0.0 <= g["engine.padding_waste"] < 1.0
    assert g["engine.queue_depth"] == 0          # drained
    assert g["engine.tracks_per_sec"] > 0
    assert h["engine.record_latency_seconds"]["count"] == 6
    assert h["engine.record_latency_seconds"]["p50"] > 0
    assert h["engine.wave_occupancy"]["count"] == engine.waves
    assert h["span.engine.step"]["count"] == engine.waves


def test_engine_threaded_submits_counted_exactly():
    obs.enable()
    model = wiener_velocity()
    engine = TrajectoryEngine(model, batch=4, method="parallel_rts",
                              options=ParallelOptions(nsub=NSUB))
    per_thread = 5

    def submit_some(seed):
        for ts, y in _engine_records([10] * per_thread,
                                     np.random.default_rng(seed)):
            engine.submit(ts, y)

    threads = [threading.Thread(target=submit_some, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert engine.run() == 4 * per_thread
    snap = obs.snapshot()
    assert snap["counters"]["engine.submitted"] == 4 * per_thread
    assert snap["counters"]["engine.completed"] == 4 * per_thread
    assert (snap["histograms"]["engine.record_latency_seconds"]["count"]
            == 4 * per_thread)


# -- tracing ----------------------------------------------------------------


def test_span_trees_nest_and_time():
    obs.enable()
    with obs.trace_span("outer"):
        with obs.trace_span("inner"):
            pass
        with obs.trace_span("inner"):
            pass
    trees = obs.span_trees()
    assert len(trees) == 1
    root = trees[0]
    assert root["name"] == "outer"
    assert [c["name"] for c in root["children"]] == ["inner", "inner"]
    assert root["dur_s"] >= max(c["dur_s"] for c in root["children"]) >= 0
    snap = obs.snapshot()
    assert snap["histograms"]["span.outer"]["count"] == 1
    assert snap["histograms"]["span.inner"]["count"] == 2
