"""Statistical linear regression (SLR) through sigma points.

Instead of differentiating ``g`` at a point, SLR fits the best affine
surrogate *in expectation* under a Gaussian spread ``N(m, P)`` around the
nominal point (Yaghoobi et al., arXiv 2102.00514, section 3):

    zbar = E[g(x)]            (sigma-point quadrature)
    Pxz  = Cov[x, g(x)]
    Pzz  = Cov[g(x)]
    A    = Pxz^T P^{-1}
    b    = zbar - A m
    Omega = Pzz - A P A^T     (PSD linearisation-error covariance)

``Omega`` is folded into the process / measurement noise by the grid
builder (``Q + Omega_f``, ``R + Omega_h``), which is exactly what turns
the iterated smoother into the posterior-linearisation smoother.  For an
affine ``g`` the regression is exact: ``A`` and ``b`` are recovered to
machine precision and ``Omega == 0``, so SLR coincides with Taylor on
linear models (pinned by tests).

Everything here is jit/vmap/scan-safe: the sigma points are host-side
static constants (see :mod:`repro.linearize.sigma_points`); the per-point
regression is pure ``jnp``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

import repro.obs as obs
from .base import Linearization, register_linearization
from .sigma_points import (
    Cubature,
    GaussHermite,
    SigmaPointFamily,
    Unscented,
    unit_points,
)


def slr_linearize_point(g: Callable, m, t, cov, family: SigmaPointFamily,
                        spread: float = 1.0):
    """SLR of ``g(., t)`` about ``N(m, spread * cov)``.

    ``m`` ``(nx,)``, ``cov`` ``(nx, nx)`` (symmetric PD).  Returns
    ``(A, b, Omega)`` with ``Omega`` symmetrised PSD ``(nz, nz)``.
    """
    n = m.shape[-1]
    unit = unit_points(family, n)
    pts = jnp.asarray(unit.points, dtype=m.dtype)
    wm = jnp.asarray(unit.wm, dtype=m.dtype)
    wc = jnp.asarray(unit.wc, dtype=m.dtype)

    P = spread * cov
    L = jnp.linalg.cholesky(P)
    xs = m + pts @ L.T                     # (S, nx)
    zs = jax.vmap(lambda x: g(x, t))(xs)   # (S, nz)

    zbar = wm @ zs
    dx = xs - m
    dz = zs - zbar
    Pxz = jnp.einsum("s,si,sj->ij", wc, dx, dz)
    Pzz = jnp.einsum("s,si,sj->ij", wc, dz, dz)

    # A = Pxz^T P^{-1} via the solve against the (PD) spread covariance.
    A = jnp.linalg.solve(P, Pxz).T
    b = zbar - A @ m
    Omega = Pzz - A @ P @ A.T
    Omega = 0.5 * (Omega + Omega.T)
    return A, b, Omega


@dataclasses.dataclass(frozen=True)
class SLR(Linearization):
    """Sigma-point statistical linear regression.

    ``family`` picks the quadrature rule; ``spread`` scales the
    covariance the regression averages over (1.0 = use the supplied
    spread covariance as-is).  The grid builder supplies the model's
    ``P0`` as the spread covariance -- a PRIOR-width proxy, since
    posterior covariances are not plumbed through yet -- so the default
    shrinks it (``spread=0.01``) toward the posterior scale; as
    ``spread -> 0`` SLR converges to Taylor for smooth models.  Frozen
    and hashable, so it can sit inside ``IteratedOptions`` and key the
    executable cache.
    """

    family: SigmaPointFamily = Unscented()
    spread: float = 0.01

    has_residual = True

    def __post_init__(self) -> None:
        if not isinstance(self.family, SigmaPointFamily):
            raise TypeError(
                f"family must be a SigmaPointFamily, got "
                f"{type(self.family).__name__}")
        if not (isinstance(self.spread, (int, float)) and self.spread > 0):
            raise ValueError(f"spread must be > 0, got {self.spread!r}")

    def __call__(self, g: Callable, x, t, cov=None):
        if cov is None:
            raise ValueError(
                "SLR needs a spread covariance (cov=None is only valid for "
                "derivative-based linearisations)")
        return slr_linearize_point(g, x, t, cov, self.family, self.spread)

    def linearize_grid(self, g: Callable, xb, tl, covs=None):
        if covs is None:
            raise ValueError(
                "SLR needs per-point spread covariances on the grid")
        if obs.enabled():
            obs.inc("linearize.slr.regressions", xb.shape[0])
            obs.inc("linearize.slr.sigma_points",
                    xb.shape[0] * self.family.num_points(xb.shape[-1]))
        with obs.trace_span("slr"):
            def one(x, t, c):
                return slr_linearize_point(g, x, t, c, self.family,
                                           self.spread)
            return jax.vmap(one)(xb, tl, covs)

    @property
    def obs_name(self) -> str:
        return self.family.name

    def num_points(self, n: int) -> int:
        return self.family.num_points(n)


def unscented(alpha: float = 1.0, beta: float = 0.0, kappa=None,
              spread: float = 0.01) -> SLR:
    """SLR through unscented-transform points (2n + 1)."""
    return SLR(Unscented(alpha, beta, kappa), spread)


def cubature(spread: float = 0.01) -> SLR:
    """SLR through spherical-radial cubature points (2n)."""
    return SLR(Cubature(), spread)


def gauss_hermite(order: int = 3, spread: float = 0.01) -> SLR:
    """SLR through tensor-product Gauss-Hermite points (order**n)."""
    return SLR(GaussHermite(order), spread)


register_linearization("unscented", unscented)
register_linearization("cubature", cubature)
register_linearization("gauss_hermite", gauss_hermite)
