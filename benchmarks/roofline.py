"""Roofline analysis: join the dry-run artifacts with the analytic cost
model and emit the per-(arch x shape x mesh) table.

Terms (seconds per step, TPU v5e targets):
  compute    = FLOPs / (chips * 197e12)           [bf16 peak]
  memory     = per-device HBM bytes / 819e9
  collective = per-device collective bytes / 50e9  [per-link ICI]

FLOPs and HBM bytes come from benchmarks/flops.py (analytic, validated
against XLA on loop-free lowerings -- see module docstring for why raw
``cost_analysis()`` cannot be used under scan-over-layers); collective
bytes are MEASURED from the compiled HLO with the loop-aware structural
parse in launch/dryrun.py.

Reported per cell:
  * the three terms, the dominant one (= bottleneck),
  * MODEL_FLOPS = 6*N(_active)*tokens (2*N for inference cells),
  * ratio MODEL_FLOPS / analytic FLOPs (useful-compute fraction: catches
    remat recompute, causal waste, MoE capacity padding),
  * roofline fraction = MODEL_FLOPS / (chips * peak * max(terms)) -- the
    MFU the step would reach running exactly at the roofline bound,
  * a one-line note on what moves the dominant term.

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.flops import model_flops, step_cost  # noqa: E402

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link (conservative single-link)


def _notes(dom, cfg, shape, rec):
    coll = rec.get("collectives", {}).get("bytes", {})
    biggest = max(coll, key=coll.get) if coll else "?"
    if dom == "collective":
        return (f"dominated by {biggest}; move TP reduces to "
                f"reduce-scatter+all-gather (seq-parallel norms), bf16 "
                f"collectives, or shrink TP degree for this size")
    if dom == "memory":
        if shape.kind == "decode":
            return ("weight/KV streaming bound: batch more queries per "
                    "weight read, quantise KV cache, or shrink TP to cut "
                    "per-chip weight re-reads")
        return ("activation traffic bound: fuse norms/elementwise, larger "
                "attention chunks, fewer remat boundaries")
    return ("compute bound: raise useful-flop fraction (causal-skip "
            "schedule, less remat recompute, tighter MoE capacity)")


def analyse(dryrun_dir: str, causal_skip_tags=("cskip",)):
    from repro.config import SHAPE_SUITE, get_config

    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            rows.append({
                "mesh": rec["mesh"], "arch": rec["arch"],
                "shape": rec["shape"], "status": "skipped",
                "note": rec["skip_reason"], "tag": rec.get("tag", ""),
            })
            continue
        if rec.get("status") != "ok":
            rows.append({
                "mesh": rec["mesh"], "arch": rec["arch"],
                "shape": rec["shape"], "status": "FAILED",
                "note": rec.get("error", "")[:120],
                "tag": rec.get("tag", ""),
            })
            continue
        cfg = get_config(rec["arch"])
        shape = next(s for s in SHAPE_SUITE if s.name == rec["shape"])
        chips = rec["num_devices"]
        causal_skip = rec.get("tag", "") in causal_skip_tags
        cost = step_cost(cfg, shape, chips, causal_skip=causal_skip)
        mf = model_flops(cfg, shape)

        # prefer wire-byte analysis from the archived HLO (ring-algorithm
        # costs per op kind); fall back to the dry-run's output-byte sums
        coll_bytes = rec["collectives"]["total_bytes"]
        coll_detail = rec["collectives"]["bytes"]
        hlo_path = rec.get("hlo_path")
        if hlo_path and os.path.exists(hlo_path):
            try:
                from repro.launch.hlo_parse import (
                    collective_analysis, load_hlo)
                wa = collective_analysis(load_hlo(hlo_path))
                coll_bytes = wa["total_wire_bytes"]
                coll_detail = wa["wire_bytes"]
                rec["collectives"]["bytes"] = coll_detail
            except Exception:
                pass

        t_comp = cost.flops / (chips * PEAK_FLOPS)
        t_mem = cost.hbm_bytes / HBM_BW
        t_coll = coll_bytes / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        step_lb = max(terms.values())
        frac = mf / (chips * PEAK_FLOPS * step_lb) if step_lb else 0.0

        rows.append({
            "mesh": rec["mesh"], "arch": rec["arch"],
            "shape": rec["shape"], "status": "ok",
            "tag": rec.get("tag", ""),
            "chips": chips,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dom,
            "model_flops": mf,
            "analytic_flops": cost.flops,
            "useful_frac": mf / cost.flops,
            "roofline_frac": frac,
            "hlo_flops_per_dev": rec["cost_analysis"].get("flops", 0),
            "coll_bytes": coll_bytes,
            "mem_gb_per_dev": (
                rec["memory_analysis"].get("argument_size_in_bytes", 0)
                + rec["memory_analysis"].get("temp_size_in_bytes", 0))
                / 2**30,
            "note": _notes(dom, cfg, shape, rec),
        })
    return rows


def to_markdown(rows) -> str:
    hdr = ("| mesh | arch | shape | tag | comp s | mem s | coll s | "
           "dominant | useful | roofline | dev GB | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} | "
                f"{r.get('tag','')} | - | - | - | {r['status']} | - | - |"
                f" - | {r['note']} |\n")
            continue
        out.append(
            f"| {r['mesh']} | {r['arch']} | {r['shape']} | {r['tag']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_frac']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['mem_gb_per_dev']:.1f} | {r['note'][:70]} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline.md")
    ap.add_argument("--csv", default="artifacts/roofline.csv")
    args = ap.parse_args()
    rows = analyse(args.dir)
    md = to_markdown(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)
    import csv as _csv
    keys = ["mesh", "arch", "shape", "tag", "status", "chips",
            "t_compute_s", "t_memory_s", "t_collective_s", "dominant",
            "model_flops", "analytic_flops", "useful_frac",
            "roofline_frac", "coll_bytes", "mem_gb_per_dev", "note"]
    with open(args.csv, "w", newline="") as f:
        w = _csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        w.writerows(rows)
    print(md)
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    bad = sum(1 for r in rows if r["status"] == "FAILED")
    print(f"# cells: {ok} ok, {sk} skipped, {bad} failed")


if __name__ == "__main__":
    main()
