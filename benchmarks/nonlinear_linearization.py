"""Linearisation strategies on the coordinated-turn model: per-iteration
wall time and final Onsager-Machlup cost of the iterated smoother with
``taylor`` (Jacobian IEKS) vs sigma-point SLR (``unscented`` /
``cubature``).

One AOT-compiled solve per (strategy, T); ``us_per_iter`` is the full
solve wall time divided by the iteration count (every iteration is one
linearise + solve pass), ``derived`` carries the final cost -- the
accuracy axis the timing is traded against (docs/LINEARIZATION.md).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

STRATEGIES = ("taylor", "unscented", "cubature")


def run(T_list=(64, 256), nsub=10, mode="discrete", repeats=3,
        iterations=5, strategies=STRATEGIES, smoke=False):
    from repro.configs.coordinated_turn import CoordinatedTurnConfig
    from repro.core import (
        Estimator, ParallelOptions, Problem, SigmaPointOptions,
        simulate_nonlinear, time_grid,
    )

    if smoke:
        T_list, repeats, iterations = (8,), 1, 2
    ccfg = CoordinatedTurnConfig(iterations=iterations)
    model = ccfg.model()
    rows = []
    for T in T_list:
        N = T * nsub
        ts = time_grid(ccfg.t0, ccfg.tf, N, dtype=jnp.float32)
        _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(3))
        for strategy in strategies:
            est = Estimator(
                model, method="sigma_point",
                options=SigmaPointOptions(
                    iterations=iterations, linearization=strategy,
                    inner=ParallelOptions(nsub=nsub, mode=mode)))
            compiled = est.lower(
                Problem.single(model, ts, y)).compile()   # AOT executable
            fn = lambda yy: compiled(ts, yy)
            cost = float(fn(y).cost)
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn(y).x.block_until_ready()
            dt = (time.perf_counter() - t0) / repeats
            rows.append({
                "name": f"nonlin/{strategy}/T{T}",
                "us_per_call": dt * 1e6 / iterations,
                "derived": f"final_cost={cost:.4f}",
            })
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
