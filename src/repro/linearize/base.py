"""The ``Linearization`` strategy protocol and its registry.

A linearisation turns one nonlinear function ``g(x, t)`` into the affine
surrogate ``g(x, t) ~= A x + b`` about a nominal point, optionally with a
residual covariance ``Omega`` quantifying the surrogate's error:

    (A, b, Omega) = linearization(g, xbar, t, cov)

``cov`` is the spread the linearisation may average over (statistical
linear regression); derivative-based strategies ignore it.  ``Omega`` is
``None`` for exact-at-a-point strategies (Taylor) and a PSD matrix for
regression strategies -- the grid builder folds it into the process /
measurement noise (``Q + Omega_f``, ``R + Omega_h``), which is what makes
posterior-linearisation smoothers well behaved on strongly nonlinear
models (Yaghoobi et al., arXiv 2102.00514, section 3).

Strategies are frozen dataclasses: hashable (they ride inside the options
dataclasses into the executable-cache key) and stateless (every method is
jit/vmap/scan-safe -- sigma-point generation happens host-side on static
shapes only).  New strategies plug in with :func:`register_linearization`
and become valid ``IteratedOptions(linearization=...)`` strings without
touching any call site.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax

Array = "jax.Array"


@dataclasses.dataclass(frozen=True)
class Linearization:
    """Base strategy: affine surrogate of ``g(x, t)`` about a point.

    Subclasses implement :meth:`__call__` (one grid point) and declare
    ``has_residual``: ``False`` means ``Omega`` is statically ``None``
    and the grid builder skips the noise augmentation entirely (the
    Taylor path stays bit-exact with the pre-subsystem code).
    """

    #: does this strategy produce a residual covariance Omega?
    has_residual = False

    def __call__(self, g: Callable, x, t, cov=None) -> Tuple:
        """Linearise ``g`` about ``x`` (spread ``cov``) at time ``t``;
        returns ``(A, b, Omega)`` with ``Omega`` possibly ``None``."""
        raise NotImplementedError

    def linearize_grid(self, g: Callable, xb, tl, covs=None):
        """Vectorised linearisation over a grid of nominal points.

        ``xb`` ``(N, nx)``, ``tl`` ``(N,)``, ``covs`` ``(N, nx, nx)`` (or
        ``None`` for derivative strategies).  Returns grid arrays
        ``(A, b, Omega)`` -- ``Omega`` is ``None`` iff ``has_residual``
        is ``False``.
        """
        if covs is None:
            def one(x, t):
                return self(g, x, t)
            return jax.vmap(one)(xb, tl)
        def one(x, t, c):
            return self(g, x, t, c)
        return jax.vmap(one)(xb, tl, covs)

    @property
    def obs_name(self) -> str:
        """Metric-taxonomy slug (``linearize.<obs_name>.*``)."""
        return type(self).__name__.lower()

    def num_points(self, n: int) -> int:
        """Function evaluations per grid point (1 for derivative
        strategies; the sigma-point count for regression strategies)."""
        return 1


_LINEARIZATIONS: Dict[str, Callable[[], Linearization]] = {}


def register_linearization(name: str, factory: Callable[[], Linearization],
                           *, overwrite: bool = False) -> None:
    """Register ``factory`` (zero-arg, returns a :class:`Linearization`)
    under ``name``, making it a valid ``linearization=`` string."""
    if name in _LINEARIZATIONS and not overwrite:
        raise ValueError(f"linearization {name!r} already registered")
    _LINEARIZATIONS[name] = factory


def linearization_names() -> Tuple[str, ...]:
    return tuple(_LINEARIZATIONS)


def get_linearization(spec: "Optional[str | Linearization]") -> Linearization:
    """Resolve a ``linearization=`` value: ``None`` -> the Taylor default,
    a registered name -> its default instance, an instance -> itself."""
    if spec is None:
        spec = "taylor"
    if isinstance(spec, Linearization):
        return spec
    if isinstance(spec, str):
        try:
            return _LINEARIZATIONS[spec]()
        except KeyError:
            raise ValueError(
                f"linearization must be one of {linearization_names()} or a "
                f"Linearization instance, got {spec!r}") from None
    raise TypeError(
        f"linearization must be a str or Linearization instance, got "
        f"{type(spec).__name__}")
