"""StreamingEngine: fixed-lag windows must agree with one-shot offline
solves, eviction/commit bookkeeping, threaded push/solve, validation, and
the ``stream.*`` obs taxonomy."""
import threading

import jax
import numpy as np
import pytest

from helpers import coordinated_turn, wiener_velocity
from repro import obs
from repro.core import (
    Estimator, IteratedOptions, ParallelOptions, Problem, simulate_linear,
    simulate_nonlinear, time_grid,
)
from repro.serving import StreamingEngine

NSUB = 5
OPTIONS = ParallelOptions(nsub=NSUB, mode="discrete")


def _linear_data(N, seed=0, T=None):
    model = wiener_velocity()
    ts = time_grid(0.0, (N / 10.0) if T is None else T, N)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(seed))
    return model, np.asarray(ts), np.asarray(y)


def _stream(eng, tid, ts, y, chunk):
    """Push (ts, y) in ``chunk``-interval pieces, draining after each."""
    N = y.shape[0]
    i = 0
    while i < N:
        k = min(chunk, N - i)
        eng.push(tid, ts[i + 1:i + 1 + k], y[i:i + k])
        i += k
        eng.run()


# -- agreement with one-shot offline solves -------------------------------


def test_linear_window_agrees_with_offline_exactly():
    """The live window of a fixed-lag stream equals the full offline MAP
    restricted to the window -- the information-form prior handoff is
    exact for linear models (rtol 1e-9 demanded, ~1e-15 observed)."""
    model, ts, y = _linear_data(60)
    ref = np.asarray(
        Estimator(model, options=OPTIONS).solve(
            Problem.single(model, ts, y)).x)
    eng = StreamingEngine(model, lag=15, batch=4, options=OPTIONS)
    tid = eng.open_track(ts[0])
    _stream(eng, tid, ts, y, chunk=7)
    full = np.asarray(eng.estimate(tid).x)
    assert full.shape == ref.shape
    scale = np.max(np.abs(ref))
    lag = eng.lag
    np.testing.assert_allclose(
        full[-lag - 1:], ref[-lag - 1:], rtol=0, atol=1e-9 * scale)


def test_linear_committed_state_is_truncated_offline_map():
    """A committed (evicted) state equals the offline MAP of the problem
    truncated at the window end at eviction time -- the chained-window
    exactness invariant, point by point."""
    model, ts, y = _linear_data(40)
    est = Estimator(model, options=OPTIONS)
    lag = 10
    eng = StreamingEngine(model, lag=lag, batch=4, options=OPTIONS)
    tid = eng.open_track(ts[0])
    scale = np.max(np.abs(y))
    for j in range(1, y.shape[0] + 1):
        eng.push(tid, ts[j:j + 1], y[j - 1:j])
        eng.run()
        committed = eng.committed(tid)
        if committed is None:
            continue
        # the point evicted by THIS solve saw measurements up to j
        k = committed.x.shape[0] - 1
        off = est.solve(Problem.ragged(model, [(ts[:j + 1], y[:j])]))[0]
        np.testing.assert_allclose(
            committed.x[k], np.asarray(off.x)[k], rtol=0, atol=1e-9 * scale)
        np.testing.assert_allclose(
            committed.S[k], np.asarray(off.S)[k], rtol=0,
            atol=1e-9 * np.max(np.abs(np.asarray(off.S))))


def test_linear_fixed_lag_error_decays_with_lag():
    """The committed history converges to the full offline MAP as the lag
    grows (fixed-lag truncation error, not a bug)."""
    model, ts, y = _linear_data(60)
    ref = np.asarray(
        Estimator(model, options=OPTIONS).solve(
            Problem.single(model, ts, y)).x)
    scale = np.max(np.abs(ref))

    def stream_err(lag):
        eng = StreamingEngine(model, lag=lag, batch=4, options=OPTIONS)
        tid = eng.open_track(ts[0])
        _stream(eng, tid, ts, y, chunk=10)
        full = np.asarray(eng.estimate(tid).x)
        return np.max(np.abs(full - ref)) / scale

    e_short, e_long = stream_err(5), stream_err(25)
    assert e_long < e_short
    assert e_long < 1e-3


def test_nonlinear_streaming_matches_offline():
    """Warm-started nonlinear streaming agrees with the one-shot iterated
    offline solve (rtol 1e-6 demanded; both converged, ~1e-9 observed).
    Lag exceeds the track length so no eviction -- this isolates the
    streaming plumbing (snapshots, per-row warm starts) from the
    fixed-lag truncation, which the linear tests quantify."""
    model = coordinated_turn()
    N = 50
    ts = time_grid(0.0, 5.0, N)
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(0))
    ts, y = np.asarray(ts), np.asarray(y)
    opts = IteratedOptions(iterations=12,
                           inner=ParallelOptions(nsub=NSUB, mode="discrete"))
    ref = np.asarray(
        Estimator(model, options=opts).solve(
            Problem.single(model, ts, y)).x)
    eng = StreamingEngine(model, lag=128, batch=4, options=opts)
    tid = eng.open_track(ts[0])
    _stream(eng, tid, ts, y, chunk=10)
    full = np.asarray(eng.estimate(tid).x)
    scale = np.max(np.abs(ref))
    np.testing.assert_allclose(full, ref, rtol=0, atol=1e-6 * scale)


def test_nonlinear_fixed_lag_window():
    """With eviction the nonlinear window tracks the offline MAP to the
    fixed-lag truncation error, which shrinks as the lag grows."""
    model = coordinated_turn()
    N = 60
    ts = time_grid(0.0, 6.0, N)
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(1))
    ts, y = np.asarray(ts), np.asarray(y)
    opts = IteratedOptions(iterations=8,
                           inner=ParallelOptions(nsub=NSUB, mode="discrete"))
    ref = np.asarray(
        Estimator(model, options=opts).solve(
            Problem.single(model, ts, y)).x)
    scale = np.max(np.abs(ref))

    def window_err(lag):
        eng = StreamingEngine(model, lag=lag, batch=4, options=opts)
        tid = eng.open_track(ts[0])
        _stream(eng, tid, ts, y, chunk=10)
        win = np.asarray(eng.estimate(tid).x)[-lag - 1:]
        return np.max(np.abs(win - ref[-lag - 1:])) / scale

    e_short, e_long = window_err(10), window_err(40)
    assert e_long < e_short
    assert e_long < 1e-2


# -- eviction / bookkeeping ----------------------------------------------


def test_eviction_boundaries():
    model, ts, y = _linear_data(40)
    lag = 10
    eng = StreamingEngine(model, lag=lag, batch=2, options=OPTIONS)
    tid = eng.open_track(ts[0])
    _stream(eng, tid, ts, y, chunk=10)
    track = eng._tracks[tid]
    # window retains exactly lag intervals after each eviction-triggering
    # solve; everything older is committed
    assert track.y.shape[0] == lag
    assert track.offset == 40 - lag
    committed = eng.committed(tid)
    assert committed.x.shape == (40 - lag, model.nx)
    window = eng.window(tid)
    assert window.x.shape == (lag + 1, model.nx)
    full = eng.estimate(tid)
    assert full.x.shape == (41, model.nx)
    # stitch is committed + window, in order
    np.testing.assert_array_equal(full.x[:40 - lag], committed.x)
    np.testing.assert_array_equal(full.x[40 - lag:], window.x)
    # close() returns the same final estimate and removes the track
    final = eng.close(tid)
    np.testing.assert_array_equal(final.x, full.x)
    assert eng.tracks() == []
    with pytest.raises(KeyError, match="unknown track"):
        eng.estimate(tid)


def test_no_eviction_before_lag():
    model, ts, y = _linear_data(10)
    eng = StreamingEngine(model, lag=20, batch=2, options=OPTIONS)
    tid = eng.open_track(ts[0])
    _stream(eng, tid, ts, y, chunk=5)
    assert eng.committed(tid) is None
    assert eng.estimate(tid).x.shape == (11, wiener_velocity().nx)


def test_multi_track_waves_batch_together():
    """Windows from different tracks share waves: 4 tracks at the same
    bucket drain in ceil(4/batch) waves, and each track's estimate
    matches its own single-track stream."""
    model, ts, y = _linear_data(20)
    eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
    tids = [eng.open_track(ts[0]) for _ in range(4)]
    datasets = []
    for i, tid in enumerate(tids):
        _, yi = simulate_linear(model, ts, jax.random.PRNGKey(100 + i))
        datasets.append(np.asarray(yi))
        eng.push(tid, ts[1:], datasets[-1])
    assert eng.due() == 4
    solved = eng.run()
    assert solved == 4
    assert eng.waves == 2          # batch=2 -> two full waves, no recycling
    for tid, yi in zip(tids, datasets):
        solo = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
        stid = solo.open_track(ts[0])
        solo.push(stid, ts[1:], yi)
        solo.run()
        np.testing.assert_allclose(
            np.asarray(eng.estimate(tid).x),
            np.asarray(solo.estimate(stid).x), rtol=0, atol=1e-10)


def test_threaded_push_and_solve():
    """Client threads push concurrently while a solver thread drains;
    every track's final estimate matches its offline reference window."""
    model, ts, y = _linear_data(30)
    lag = 30                        # no eviction: final estimate == offline
    eng = StreamingEngine(model, lag=lag, batch=2, options=OPTIONS)
    est = Estimator(model, options=OPTIONS)
    n_tracks = 4
    tids = [eng.open_track(ts[0]) for _ in range(n_tracks)]
    datasets = [
        np.asarray(simulate_linear(model, ts, jax.random.PRNGKey(7 + i))[1])
        for i in range(n_tracks)]
    stop = threading.Event()

    def solver():
        while not stop.is_set() or eng.due():
            if not eng.step():
                stop.wait(0.001)

    def client(tid, yi):
        for i in range(0, 30, 6):
            eng.push(tid, ts[i + 1:i + 7], yi[i:i + 6])

    solver_t = threading.Thread(target=solver)
    solver_t.start()
    clients = [threading.Thread(target=client, args=(tid, yi))
               for tid, yi in zip(tids, datasets)]
    for t in clients:
        t.start()
    for t in clients:
        t.join()
    stop.set()
    solver_t.join()
    assert eng.due() == 0
    for tid, yi in zip(tids, datasets):
        ref = np.asarray(est.solve(Problem.single(model, ts, yi)).x)
        got = np.asarray(eng.estimate(tid).x)
        np.testing.assert_allclose(got, ref, rtol=0,
                                   atol=1e-9 * np.max(np.abs(ref)))


def test_estimate_refresh_waits_for_in_flight_solve():
    """Regression: estimate(refresh=True) returned the PREVIOUS solve's
    state when a step() had already snapshotted the track and was
    mid-solve -- the track was no longer due, so _refresh was a no-op
    and the in-flight pushes were silently excluded (close() inherited
    the same gap).  It now waits for the in-flight wave to land, then
    solves anything newer."""
    model, ts, y = _linear_data(20)
    eng = StreamingEngine(model, lag=30, batch=1, options=OPTIONS)
    tid = eng.open_track(ts[0])
    eng.push(tid, ts[1:6], y[:5])
    eng.run()
    entered, release = threading.Event(), threading.Event()
    real_solve = eng.estimator.solve

    def slow_solve(problem):
        entered.set()
        assert release.wait(60.0)
        return real_solve(problem)

    eng.estimator.solve = slow_solve
    got = {}
    try:
        eng.push(tid, ts[6:11], y[5:10])
        solver = threading.Thread(target=eng.step)
        solver.start()
        assert entered.wait(60.0)            # track snapshotted, mid-solve
        eng.push(tid, ts[11:21], y[10:20])   # arrives while in flight
        reader = threading.Thread(
            target=lambda: got.update(x=np.asarray(eng.estimate(tid).x)))
        reader.start()
        reader.join(0.5)
        assert reader.is_alive(), \
            "estimate(refresh=True) returned while a solve was in flight"
        release.set()
        solver.join(60.0)
        reader.join(60.0)
        assert not reader.is_alive()
    finally:
        eng.estimator.solve = real_solve
        release.set()
    # FRESH: both the in-flight and the mid-solve pushes are included
    assert got["x"].shape == (21, model.nx)
    ref = np.asarray(
        Estimator(model, options=OPTIONS).solve(
            Problem.single(model, ts, y)).x)
    np.testing.assert_allclose(got["x"], ref, rtol=0,
                               atol=1e-9 * np.max(np.abs(ref)))
    assert not eng._inflight                 # registry drained


def test_push_during_solve_marks_due_again():
    model, ts, y = _linear_data(20)
    eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
    tid = eng.open_track(ts[0])
    eng.push(tid, ts[1:11], y[:10])
    eng.run()
    assert eng.due() == 0
    eng.push(tid, ts[11:21], y[10:20])
    assert eng.due() == 1


# -- validation ----------------------------------------------------------


def test_push_validation():
    model, ts, y = _linear_data(10)
    eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
    tid = eng.open_track(ts[0])
    with pytest.raises(ValueError, match="strictly increasing"):
        eng.push(tid, [0.2, 0.1], y[:2])
    # at/before the track origin: unrepresentable -> counted drop, no error
    assert eng.push(tid, [0.0], y[:1])["dropped_late"] == 1
    assert eng.due() == 0                    # a pure drop is not new work
    with pytest.raises(ValueError, match="measurement dimension"):
        eng.push(tid, ts[1:2], np.zeros((1, 3)))
    with pytest.raises(ValueError, match=r"\(K, ny\)"):
        eng.push(tid, ts[1:3], y[:1])        # K mismatch
    with pytest.raises(KeyError, match="unknown track"):
        eng.push(99, ts[1:2], y[:1])
    eng.push(tid, ts[1:3], y[:2])
    with pytest.raises(ValueError, match="duplicate"):
        eng.push(tid, ts[2:4], y[1:3])       # re-sends the last point


def test_estimate_before_solve_raises():
    model, ts, y = _linear_data(10)
    eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
    tid = eng.open_track(ts[0])
    with pytest.raises(ValueError, match="no estimate yet"):
        eng.estimate(tid)
    eng.push(tid, ts[1:], y)
    with pytest.raises(ValueError, match="no estimate yet"):
        eng.window(tid)                      # pushed but not solved
    eng.run()
    assert eng.estimate(tid).x.shape == (11, model.nx)


def test_constructor_validation():
    model = wiener_velocity()
    with pytest.raises(ValueError, match="lag"):
        StreamingEngine(model, lag=0)
    with pytest.raises(ValueError, match="batch"):
        StreamingEngine(model, batch=0)
    with pytest.raises(ValueError, match="duplicate_policy"):
        StreamingEngine(model, duplicate_policy="overwrite")
    with pytest.raises(ValueError, match="reorder_slack"):
        StreamingEngine(model, reorder_slack=-1)
    with pytest.raises(ValueError, match="max_committed_states"):
        StreamingEngine(model, max_committed_states=-1)
    with pytest.raises(ValueError, match="committed_error_target"):
        StreamingEngine(model, lag_min=2)        # adaptive knob w/o target
    with pytest.raises(ValueError, match="committed_error_target"):
        StreamingEngine(model, committed_error_target=0.0)
    with pytest.raises(ValueError, match="lag_max"):
        StreamingEngine(model, committed_error_target=0.1,
                        lag_min=8, lag_max=4)
    # adaptive initial lag is clamped into [lag_min, lag_max]
    eng = StreamingEngine(model, lag=32, committed_error_target=0.1,
                          lag_min=2, lag_max=8)
    assert eng.lag == 8


# -- satellite regressions -------------------------------------------------


def test_estimate_solves_due_tracks_on_demand():
    """Regression: estimate() used to silently return the STALE window
    when pushes arrived after the last solve -- committed + win_x simply
    ignored track.y rows newer than the last step().  It now solves due
    tracks on demand (and refresh=False documents the old fast read)."""
    model, ts, y = _linear_data(20)
    eng = StreamingEngine(model, lag=30, batch=2, options=OPTIONS)
    tid = eng.open_track(ts[0])
    eng.push(tid, ts[1:11], y[:10])
    eng.run()
    eng.push(tid, ts[11:21], y[10:20])       # due again -- but NO step()
    stale = eng.estimate(tid, refresh=False)
    assert stale.x.shape == (11, model.nx)   # the documented fast read
    fresh = eng.estimate(tid)                # solve-on-demand default
    assert fresh.x.shape == (21, model.nx)
    assert eng.due() == 0
    ref = np.asarray(
        Estimator(model, options=OPTIONS).solve(
            Problem.single(model, ts, y)).x)
    np.testing.assert_allclose(np.asarray(fresh.x), ref, rtol=0,
                               atol=1e-9 * np.max(np.abs(ref)))


def test_max_committed_states_bounds_history():
    """Regression: committed_x/S/v grew without bound on long-lived
    tracks.  With max_committed_states the oldest states are trimmed, the
    trim is counted, and the readers return the retained suffix."""
    model, ts, y = _linear_data(40)
    was = obs.enabled()
    obs.reset()
    obs.enable()
    try:
        cap = 8
        eng = StreamingEngine(model, lag=5, batch=2, options=OPTIONS,
                              max_committed_states=cap)
        ref = StreamingEngine(model, lag=5, batch=2, options=OPTIONS)
        tid = eng.open_track(ts[0])
        _stream(eng, tid, ts, y, chunk=7)
        rid = ref.open_track(ts[0])
        _stream(ref, rid, ts, y, chunk=7)
        committed = eng.committed(tid)
        assert committed.x.shape[0] == cap
        # the retained suffix equals the unbounded run's suffix exactly
        full = ref.committed(rid)
        np.testing.assert_array_equal(committed.x, full.x[-cap:])
        np.testing.assert_array_equal(committed.S, full.S[-cap:])
        evicted = full.x.shape[0]
        assert obs.snapshot()["counters"]["stream.committed_trimmed"] == \
            evicted - cap
        # offset still counts ALL evictions; estimate() is suffix + window
        assert eng._tracks[tid].offset == evicted
        assert eng.estimate(tid).x.shape[0] == \
            cap + eng.window(tid).x.shape[0]
        final = eng.close(tid)
        assert final.x.shape[0] == cap + (40 - evicted) + 1
    finally:
        obs.reset()
        (obs.enable if was else obs.disable)()


def test_due_since_is_push_relative_not_epoch():
    """Regression: _Track.due_since started as the 0.0 sentinel, so any
    due-marking path that forgot to stamp it leaked an epoch-relative
    duration (hours) into stream.window_latency_seconds.  It now starts
    at open_track time and every due transition re-stamps it."""
    import time as _time

    model, ts, y = _linear_data(10)
    eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS,
                          duplicate_policy="replace")
    tid = eng.open_track(ts[0])
    assert _time.perf_counter() - eng._tracks[tid].due_since < 5.0
    was = obs.enabled()
    obs.reset()
    obs.enable()
    try:
        eng.push(tid, ts[1:6], y[:5])
        eng.run()
        # mark due via a NON-append path (duplicate replace), then solve
        eng.push(tid, ts[3:4], y[2:3] + 1.0)
        assert eng.due() == 1
        eng.run()
        lat = obs.histogram("stream.window_latency_seconds").summary()
        assert lat["count"] == 2
        assert lat["max"] < 60.0             # sanity: no epoch-scale value
    finally:
        obs.reset()
        (obs.enable if was else obs.disable)()


def test_default_options_are_numerically_robust():
    """Regression: the serving default must survive window lengths where
    the paper-faithful euler mode overflows (4+ blocks of nsub=10 at
    dt=0.1 on the Wiener-velocity model used to yield silent NaN)."""
    model, ts, y = _linear_data(45, T=4.5)   # dt = 0.1, bucket 80
    eng = StreamingEngine(model, lag=50, batch=2)    # options=None
    tid = eng.open_track(ts[0])
    _stream(eng, tid, ts, y, chunk=45)
    assert np.isfinite(np.asarray(eng.estimate(tid).x)).all()


# -- observability -------------------------------------------------------


def test_stream_obs_taxonomy():
    was = obs.enabled()
    obs.reset()
    obs.enable()
    try:
        model, ts, y = _linear_data(20)
        eng = StreamingEngine(model, lag=8, batch=2, options=OPTIONS)
        t0, t1 = eng.open_track(ts[0]), eng.open_track(ts[0])
        for tid in (t0, t1):
            eng.push(tid, ts[1:11], y[:10])
            eng.push(tid, ts[11:21], y[10:20])
        eng.run()
        eng.close(t1)
        snap = obs.snapshot()
        counters = snap["counters"]
        assert counters["stream.tracks_opened"] == 2
        assert counters["stream.pushes"] == 4
        assert counters["stream.pushed_intervals"] == 40
        assert counters["stream.waves"] >= 1
        assert counters["stream.completed"] == 2
        assert counters["stream.evicted_intervals"] == 2 * (20 - 8)
        assert snap["gauges"]["stream.tracks"] == 1
        assert "stream.padding_waste" in snap["gauges"]
        assert snap["gauges"]["stream.lag"] == eng.lag
        hists = snap["histograms"]
        assert hists["stream.window_latency_seconds"]["count"] == 2
        assert "stream.wave_occupancy" in hists
    finally:
        obs.reset()
        (obs.enable if was else obs.disable)()
