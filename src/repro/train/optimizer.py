"""AdamW in pure JAX with ZeRO-1 sharded states and LR schedules.

The optimizer state (m, v, fp32 master copy) triples parameter memory, so
under a mesh the states additionally shard one replicated dimension over
the DATA axis (ZeRO-1): ``zero1_logical`` rewrites the logical axes of each
tensor, replacing the first data-shardable unsharded axis with "zero1",
which ``repro.distributed.sharding.choose_pspec`` maps onto ("pod","data").
GSPMD then materialises the reduce-scatter/all-gather pattern automatically
from the in/out shardings of the jitted train step.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict
    master: dict        # fp32 master params (mixed-precision training)


def adamw_init(params) -> AdamWState:
    f32 = jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, f32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        master=jax.tree_util.tree_map(lambda p: p.astype(f32), params),
    )


def cosine_schedule(cfg: TrainConfig) -> Callable:
    def lr(step):
        warm = cfg.learning_rate * (step + 1) / max(cfg.warmup_steps, 1)
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.learning_rate * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < cfg.warmup_steps, warm, cos)

    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, state: AdamWState, cfg: TrainConfig,
                 schedule: Callable, compute_dtype=jnp.bfloat16):
    """One AdamW step; returns (new_compute_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = schedule(state.step)
    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
        return m, v, p

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, state.master)
    m = jax.tree_util.tree_map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree_util.tree_map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree_util.tree_map(
        lambda p: p.astype(compute_dtype), master)
    return new_params, AdamWState(step, m, v, master), {
        "grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding metadata
# ---------------------------------------------------------------------------

def zero1_logical(axes: tuple, shape: tuple, data_size: int) -> tuple:
    """Replace the first data-shardable unsharded axis with 'zero1'.

    An axis is eligible when its logical name would not be model-sharded
    (None or 'embed') and its size divides the data-parallel degree.
    """
    out = list(axes)
    for i, (name, dim) in enumerate(zip(axes, shape)):
        if name in (None, "embed") and dim % data_size == 0 \
                and dim >= data_size:
            out[i] = "zero1"
            return tuple(out)
    return tuple(out)


def opt_state_axes(param_axes, param_shapes, data_size: int,
                   zero1: bool = True):
    """Logical axes trees for (m, v, master) given the params' axes."""
    def leaf(ax, shp):
        return zero1_logical(ax, shp, data_size) if zero1 else ax

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    zax = jax.tree_util.tree_map(leaf, param_axes, param_shapes,
                                 is_leaf=is_ax)
    return AdamWState(step=(), m=zax, v=zax, master=zax)
