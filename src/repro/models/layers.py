"""Shared model building blocks: param specs, norms, RoPE, embeddings.

Parameters are plain nested dicts of arrays.  Every module defines its
parameters once as a ``spec`` (shape + logical axes + init), from which both
the initialised tree and the logical-axes tree are derived -- keeping the
sharding metadata impossible to drift from the parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter: shape, logical axes, init ('normal'|'zeros'|'ones'),
    fan_in (for 1/sqrt(fan_in) scaling; None -> first dim)."""
    shape: tuple
    axes: tuple
    init: str = "normal"
    fan_in: Optional[int] = None


def init_params(key, spec: dict, dtype) -> dict:
    flat = jax.tree_util.tree_leaves(
        spec, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, max(len(flat), 1))
    it = iter(keys)

    def mk(p: P):
        k = next(it)
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        fan = p.fan_in if p.fan_in is not None else p.shape[0]
        scale = 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(k, p.shape, jnp.float32) * scale
                ).astype(dtype)

    return jax.tree_util.tree_map(
        mk, spec, is_leaf=lambda x: isinstance(x, P))


def params_axes(spec: dict) -> dict:
    return jax.tree_util.tree_map(
        lambda p: p.axes, spec, is_leaf=lambda x: isinstance(x, P))


def params_shapes(spec: dict) -> dict:
    return jax.tree_util.tree_map(
        lambda p: p.shape, spec, is_leaf=lambda x: isinstance(x, P))


def stack_specs(spec: dict, num: int) -> dict:
    """Prepend a stacked 'layers' axis (for scan-over-layers weights)."""
    return jax.tree_util.tree_map(
        lambda p: P((num,) + p.shape, ("layers",) + p.axes, p.init,
                    p.fan_in if p.fan_in is not None else p.shape[0]),
        spec, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def rope_freqs(positions, head_dim: int, theta: float):
    """positions: (...,) -> cos, sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., L, H, D); cos/sin: (L, D//2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if x.ndim == cos.ndim + 2 else cos
    s = sin[..., None, :] if x.ndim == sin.ndim + 2 else sin
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]
