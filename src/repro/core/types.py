"""Core pytree types for continuous-time MAP estimation.

Notation follows the paper (Razavi, Garcia-Fernandez, Sarkka 2025):

* ``LQTElement``    -- conditional value function parameters (A, b, C, eta, J)
                       of eq. (41): V(phi, s; z, gamma) = const
                       + 1/2 phi^T J phi - phi^T eta
                       + 1/2 (z - A phi - b)^T C^{-1} (z - A phi - b).
* ``AffineElement`` -- transition pair (Phi, beta) of eq. (20)/(45)-(46):
                       phi(gamma) = Phi(gamma, s) phi(s) + beta(gamma, s).
* ``ValueFn``       -- quadratic value function V(phi) = 1/2 phi^T S phi
                       - v^T phi (eq. 14), i.e. information-form filter state.
* ``GridLQT``       -- the time-REVERSED, grid-discretised linear-affine
                       optimal control problem (eqs. 3-6 and 13) that the MAP
                       problem reduces to.  All leading axes are the substep
                       time axis of length ``N = T * n``.

Every type is a NamedTuple and therefore a JAX pytree; all algorithms are
pure functions over them so ``vmap``/``pjit``/``shard_map`` compose freely.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class LQTElement(NamedTuple):
    """Conditional value function parameters, possibly with leading batch axes."""

    A: jnp.ndarray    # (..., nx, nx)
    b: jnp.ndarray    # (..., nx)
    C: jnp.ndarray    # (..., nx, nx), symmetric PSD
    eta: jnp.ndarray  # (..., nx)
    J: jnp.ndarray    # (..., nx, nx), symmetric PSD

    @property
    def nx(self) -> int:
        return self.A.shape[-1]

    def __len__(self) -> int:  # leading (scan) axis length
        return self.A.shape[0]


class AffineElement(NamedTuple):
    """Affine trajectory-recovery element (eqs. 45-46)."""

    Phi: jnp.ndarray   # (..., nx, nx)
    beta: jnp.ndarray  # (..., nx)

    def __len__(self) -> int:
        return self.Phi.shape[0]


class ValueFn(NamedTuple):
    """Quadratic value function 1/2 phi^T S phi - v^T phi (information form)."""

    S: jnp.ndarray  # (..., nx, nx)
    v: jnp.ndarray  # (..., nx)


class GridLQT(NamedTuple):
    """Time-reversed discretised LQT problem for the MAP estimate.

    Substep ``j`` covers reversed time ``[tau_j, tau_{j+1}]`` with step
    ``dt[j]``; coefficients are evaluated at the interval (reversed-left)
    point.  The terminal (reversed) boundary carries the prior:
    ``S_T = P0^{-1}``, ``v_T = P0^{-1} m0`` (below eq. 15).
    """

    dt: jnp.ndarray      # (N,) substep lengths
    F: jnp.ndarray       # (N, nx, nx)   F~(tau_j)  = -F(t_f - tau_j)
    c: jnp.ndarray       # (N, nx)       c~(tau_j)  = -c(t_f - tau_j)
    H: jnp.ndarray       # (N, ny, nx)   H~(tau_j)
    r: jnp.ndarray       # (N, ny)
    Q: jnp.ndarray       # (N, nx, nx)   Q~ = L W L^T (invertible)
    Rinv: jnp.ndarray    # (N, ny, ny)   R~^{-1}
    y: jnp.ndarray       # (N, ny)       y~(tau_j)
    S_T: jnp.ndarray     # (nx, nx)      terminal information matrix
    v_T: jnp.ndarray     # (nx,)         terminal information vector
    lin: Optional[jnp.ndarray] = None  # (N, nx) optional extra linear cost
    # ``lin`` adds  lin_j . phi  * dt_j  to the running cost (used for the
    # optional Onsager-Machlup divergence correction, DESIGN.md S1).

    @property
    def N(self) -> int:
        return self.F.shape[0]

    @property
    def nx(self) -> int:
        return self.F.shape[-1]

    @property
    def ny(self) -> int:
        return self.H.shape[-2]


class MAPSolution(NamedTuple):
    """Result of a MAP solve, reported in ORIGINAL time order.

    ``x`` has N+1 points (t_0 .. t_f inclusive).  ``S``/``v`` are the
    information-form Kalman-Bucy filter quantities S(tau), v(tau) mapped back
    to original time (S[k] = S(tau_{N-k})), i.e. the filter information at
    time t_k.  ``cov`` is the (optional) smoothing covariance (two-filter
    method only, a beyond-paper extra).
    """

    x: jnp.ndarray            # (N+1, nx) MAP trajectory, original time
    S: jnp.ndarray            # (N+1, nx, nx)
    v: jnp.ndarray            # (N+1, nx)
    cov: Optional[jnp.ndarray] = None  # (N+1, nx, nx) smoothing covariance


# ---------------------------------------------------------------------------
# Public solution type of the unified Estimator/Problem surface
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketInfo:
    """One pad-and-bucket executable of a ragged solve."""

    n_pad: int     # padded interval count every record in the bucket shares
    records: int   # real records solved in this bucket
    batch: int     # compiled batch rows (>= records after batch padding)

    @property
    def recycled_rows(self) -> int:
        return self.batch - self.records


@dataclasses.dataclass(frozen=True)
class PaddingReport:
    """Static bucket/padding accounting attached to ragged solutions.

    ``lengths`` are the original record interval counts in submission
    order; ``buckets`` one entry per compiled executable.  Utilisation
    ratios quantify the pad-and-bucket overhead (1.0 = no padding).
    """

    lengths: Tuple[int, ...]
    buckets: Tuple[BucketInfo, ...]

    @property
    def records(self) -> int:
        return len(self.lengths)

    @property
    def real_intervals(self) -> int:
        return sum(self.lengths)

    @property
    def solved_intervals(self) -> int:
        return sum(b.n_pad * b.batch for b in self.buckets)

    @property
    def interval_utilisation(self) -> float:
        solved = self.solved_intervals
        return self.real_intervals / solved if solved else 1.0

    @property
    def row_utilisation(self) -> float:
        rows = sum(b.batch for b in self.buckets)
        return self.records / rows if rows else 1.0


@dataclasses.dataclass(frozen=True)
class Solution:
    """Result of :meth:`repro.core.Estimator.solve`: the MAP estimate of
    :class:`MAPSolution` plus diagnostics.

    Array fields may carry a leading batch axis (stacked problems).
    ``cost`` is the discretised Onsager-Machlup cost of ``x`` (the
    objective the MAP estimate minimises); for nonlinear solves
    ``cost_trace`` holds the cost after each linearise-and-solve pass
    (``cost == cost_trace[..., -1]``), the Gauss-Newton descent curve of
    the iterated smoother, and ``step_norms`` the RMS update norm
    ``||x_{i+1} - x_i||_rms`` of each pass (the iterated smoother's
    convergence indicator).  ``padding`` (static metadata) is only
    present on solutions of ragged problems.
    """

    x: jnp.ndarray                         # (..., N+1, nx) MAP trajectory
    S: jnp.ndarray                         # (..., N+1, nx, nx) filter info
    v: jnp.ndarray                         # (..., N+1, nx)
    cov: Optional[jnp.ndarray] = None      # (..., N+1, nx, nx) smoothing cov
    cost: Optional[jnp.ndarray] = None     # (...,) Onsager-Machlup cost
    cost_trace: Optional[jnp.ndarray] = None  # (..., iterations)
    step_norms: Optional[jnp.ndarray] = None  # (..., iterations)
    padding: Optional[PaddingReport] = None   # static; ragged solves only


jax.tree_util.register_dataclass(
    Solution,
    data_fields=["x", "S", "v", "cov", "cost", "cost_trace", "step_norms"],
    meta_fields=["padding"],
)
