"""Wall-time span trees: ``trace_span("estimator.solve.compile")``.

Spans nest per-thread; a completed ROOT span (no open parent on this
thread) is appended to a bounded ring, readable via :func:`span_trees`.
Every span additionally records its duration into the histogram
``span.<name>`` so :func:`repro.obs.snapshot` reports per-phase
percentiles without walking trees.

Two XLA passthroughs connect host spans to device profiles:

* ``trace_span(name, xla=True)`` wraps the body in
  ``jax.profiler.TraceAnnotation(name)`` so the span shows up on the
  host timeline of an XLA/Perfetto profile;
* :func:`xla_profile` brackets a block with ``jax.profiler.start_trace``
  / ``stop_trace`` (TensorBoard/Perfetto dump).

Both degrade to no-ops when ``jax`` (or the profiler) is unavailable --
this module never hard-imports jax.

Instrument OUTSIDE ``jit``: a span measures host wall time, so wrapping
traced code times tracing, not execution.  (Span durations are plain
floats from ``perf_counter``; no traced value is ever captured.)
"""
from __future__ import annotations

import collections
import threading
import time
from contextlib import contextmanager
from typing import List, Optional

from . import metrics

_MAX_ROOTS = 64
_roots: "collections.deque" = collections.deque(maxlen=_MAX_ROOTS)
_roots_lock = threading.Lock()
_local = threading.local()


class Span:
    """One timed region: name, start, duration, child spans."""

    __slots__ = ("name", "t0", "dur_s", "children")

    def __init__(self, name: str) -> None:
        self.name = name
        self.t0 = time.perf_counter()
        self.dur_s = 0.0
        self.children: List["Span"] = []

    def as_dict(self) -> dict:
        d = {"name": self.name, "dur_s": self.dur_s}
        if self.children:
            d["children"] = [c.as_dict() for c in self.children]
        return d


@contextmanager
def trace_span(name: str, xla: bool = False):
    """Time a region as a span under the current thread's open span (if
    any).  No-op (and allocation-free) while obs is disabled."""
    if not metrics.enabled():
        yield None
        return
    ann = None
    if xla:
        try:
            from jax.profiler import TraceAnnotation
            ann = TraceAnnotation(name)
            ann.__enter__()
        except Exception:
            ann = None
    stack = getattr(_local, "stack", None)
    if stack is None:
        stack = _local.stack = []
    span = Span(name)
    parent: Optional[Span] = stack[-1] if stack else None
    stack.append(span)
    try:
        yield span
    finally:
        span.dur_s = time.perf_counter() - span.t0
        if stack and stack[-1] is span:
            stack.pop()
        if ann is not None:
            ann.__exit__(None, None, None)
        if parent is not None:
            parent.children.append(span)
        else:
            with _roots_lock:
                _roots.append(span)
        metrics.record(f"span.{name}", span.dur_s)


def span_trees() -> List[dict]:
    """The most recent completed root spans (oldest first) as nested
    ``{"name", "dur_s", "children"}`` dicts."""
    with _roots_lock:
        return [s.as_dict() for s in _roots]


def reset() -> None:
    with _roots_lock:
        _roots.clear()


@contextmanager
def xla_profile(logdir: str):
    """Bracket a block with ``jax.profiler.start_trace(logdir)`` /
    ``stop_trace`` -- spans entered with ``xla=True`` inside the block
    appear on the profile's host timeline.  No-op if the profiler is
    unavailable."""
    started = False
    try:
        import jax.profiler
        jax.profiler.start_trace(logdir)
        started = True
    except Exception:
        pass
    try:
        yield
    finally:
        if started:
            import jax.profiler
            jax.profiler.stop_trace()
