"""Streaming fixed-lag estimation service.

``StreamingEngine`` turns the batch :class:`~repro.core.Estimator` into an
online service: clients open tracks, push measurements as they arrive, and
read back MAP estimates that are continuously refined over a sliding
window of the most recent ``lag`` intervals.

Fixed-lag smoothing, exactly
----------------------------

Every window re-solve passes the *filter information at the window's left
edge* -- ``(Solution.S[k], Solution.v[k])`` of the previous solve -- as an
information-form boundary prior (``Problem(..., prior=(S0, v0))``).  For
linear models this makes the chained window solves EXACTLY equal to the
one-shot offline MAP restricted to the window (the information recursion
is the same sums in a different order; tests verify agreement to
~1e-14).  States older than the lag are **evicted**: committed as final
:class:`~repro.core.Solution` segments and never re-solved.  A committed
state is the MAP estimate given all measurements up to ``lag`` intervals
after it -- the classic fixed-lag approximation, exact in the window and
within smoothing-decay of the full MAP behind it (docs/STREAMING.md).

Nonlinear models additionally warm-start each re-solve from the previous
window's trajectory (per-row ``x_init``), so the iterated smoother
re-linearises from an already-converged nominal instead of the prior
mean.

Late and out-of-order data
--------------------------

Real feeds deliver measurements late.  ``push`` accepts timestamps
anywhere relative to the track's grid: in-order points append, points
that land *inside the live window* are merged in time order and the
window is re-solved from the unchanged boundary prior (so in-window late
data costs nothing in exactness -- the prior only summarises evicted
history), duplicates of existing points follow the engine's
``duplicate_policy`` (``"error"`` / ``"replace"`` / ``"drop"``), and
points at or before the committed horizon are counted and dropped
(``stream.late_drops``).  ``reorder_slack`` keeps that horizon
``reorder_slack`` intervals further back than the lag -- a per-track
reorder buffer implemented by delaying eviction, so near-late data still
merges instead of dropping.  Merges racing an in-flight solve are safe:
when the mutation touches the region that solve is about to evict, the
eviction is deferred to the re-solve the merge itself queued
(``stream.deferred_evictions``), never sliced off a grid the snapshot no
longer describes.

Adaptive lag
------------

With ``committed_error_target`` set the engine self-tunes ``lag`` inside
``[lag_min, lag_max]``: every eviction observes how much the
about-to-be-committed states still moved since their previous solve (the
smoothing-decay signal) and grows the lag while that residual update
exceeds the target, shrinks it when the residual is comfortably below --
converging to the smallest lag that meets the target instead of a
hand-tuned constant (docs/STREAMING.md has the control law).

Batching
--------

Due windows (tracks with un-solved pushes) are drained in fixed-size
waves through the same machinery as :class:`TrajectoryEngine`
(:mod:`repro.serving.waves`): FIFO by first-push time, grouped by padded
bucket length, short waves recycle a live row, one compiled executable
per (bucket, batch) reused forever.  Windows across DIFFERENT tracks
batch together -- that is the point of a fixed window size: every track's
window pads to the same few bucket lengths.

Observability: with :mod:`repro.obs` enabled the engine reports the
``stream.*`` taxonomy (pushes, open tracks, per-wave occupancy/padding,
``stream.window_latency_seconds`` push-to-solve latency, eviction, late
and adaptive-lag counters) -- see docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core.estimator import Estimator, Problem
from repro.core.padding import bucket_length, slice_solution
from repro.core.sde import LinearSDE, NonlinearSDE
from repro.core.types import Solution

from .waves import (
    DUPLICATE_POLICIES,
    WaveItem,
    insert_warm_states,
    merge_measurements,
    pack_wave,
    record_wave_metrics,
    robust_default_options,
    take_wave,
)

# Adaptive-lag hysteresis: shrink only when the eviction residual is
# below this fraction of the target, so the lag settles instead of
# oscillating between grow and shrink around the threshold.  0.6 keeps
# the stable band within ~2 intervals of the smallest sufficient lag for
# smoothing-decay rates down to ~1.3x per interval while still leaving a
# 1.67x dead zone against residual jitter.
_LAG_SHRINK_RATIO = 0.6


def _zoh_resample(x: np.ndarray, snap_ts: np.ndarray,
                  cur_ts: np.ndarray) -> np.ndarray:
    """Zero-order-hold resample of a solved trajectory onto a mutated
    grid: grid points present at solve time keep their state, points
    merged since take their LEFT neighbour's, points appended since the
    final state (the same hold as :func:`insert_warm_states` /
    ``_pad_trajectory`` -- the result is only a warm-start hint)."""
    idx = np.searchsorted(snap_ts, cur_ts, side="right") - 1
    return x[np.maximum(idx, 0)]


class _Track:
    """Per-track streaming state (mutated only under the engine lock).

    ``offset`` counts evicted intervals: the live window covers track
    intervals ``[offset, offset + y.shape[0])``.  ``committed_*`` hold the
    retained evicted history; ``win_*`` the window estimate of the last
    solve (``win_ts`` its time grid, so later merges can be told apart
    from it); ``prior`` the information-form boundary at the window's
    left edge (``None`` until the first eviction -- the model prior
    applies).  ``seq`` counts data mutations (pushes/merges/replaces) and
    ``applied_seq`` the last snapshot folded back in, so out-of-order
    solve results are never applied twice or backwards.
    """

    __slots__ = ("ts", "y", "offset", "prior", "x_warm", "win_x", "win_S",
                 "win_v", "win_ts", "committed_x", "committed_S",
                 "committed_v", "due_since", "solves", "last_cost", "seq",
                 "applied_seq", "trimmed", "last_evict_delta")

    def __init__(self, t0: float):
        self.ts = np.asarray([t0], dtype=float)
        self.y: Optional[np.ndarray] = None        # (N, ny) window intervals
        self.offset = 0                            # evicted intervals
        self.prior: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self.x_warm: Optional[np.ndarray] = None   # (N+1, nx) last window x
        self.win_x: Optional[np.ndarray] = None    # last SOLVED window
        self.win_S: Optional[np.ndarray] = None
        self.win_v: Optional[np.ndarray] = None
        self.win_ts: Optional[np.ndarray] = None   # time grid of win_x rows
        self.committed_x: List[np.ndarray] = []
        self.committed_S: List[np.ndarray] = []
        self.committed_v: List[np.ndarray] = []
        # perf_counter the track last became due.  Initialised to NOW (not
        # 0.0): a track marked due by any path that forgets to stamp it
        # must never leak an epoch-relative duration into the
        # stream.window_latency_seconds histogram.
        self.due_since = time.perf_counter()
        self.solves = 0
        self.last_cost: Optional[float] = None
        self.seq = 0                # data mutations (push/merge/replace)
        self.applied_seq = -1       # seq of the last applied solve snapshot
        self.trimmed = 0            # committed states dropped by the cap
        self.last_evict_delta: Optional[float] = None

    @property
    def intervals(self) -> int:
        """Total intervals pushed so far (committed + window)."""
        return self.offset + (0 if self.y is None else self.y.shape[0])


class StreamingEngine:
    """Multi-track fixed-lag smoother service over one model.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`.
      lag: window length in INTERVALS kept live behind the newest
        measurement; anything older is evicted as committed history after
        the next solve.  Larger lag = closer to the full MAP for the
        committed states, more work per re-solve.  With
        ``committed_error_target`` set this is only the INITIAL lag.
      batch: fixed wave size -- due windows from different tracks are
        solved ``batch`` at a time (compiled once per bucket length).
      duplicate_policy: what a push whose timestamp exactly matches an
        existing window grid point does -- ``"error"`` (default: raise),
        ``"replace"`` (overwrite that measurement and re-solve) or
        ``"drop"`` (ignore it, counted in ``stream.duplicates_dropped``).
      reorder_slack: extra intervals (beyond the lag) the window keeps
        live before committing them -- a per-track reorder buffer that
        delays eviction so measurements up to ``lag + reorder_slack``
        intervals behind the newest still merge instead of being dropped
        at the committed horizon.
      max_committed_states: optional cap on the retained committed
        history per track (long-lived tracks otherwise grow without
        bound).  The OLDEST committed states are trimmed past the cap
        (``stream.committed_trimmed``); ``committed()`` / ``estimate()``
        / ``close()`` then return only the retained suffix.
      committed_error_target: enables adaptive lag.  After each eviction
        the engine measures how much the evicted states still changed in
        their final solve (max-abs update vs the previous window solve)
        and steers ``lag`` within ``[lag_min, lag_max]`` so that residual
        meets the target: grow while above, shrink while below
        ``_LAG_SHRINK_RATIO x`` the target.
      lag_min / lag_max: adaptive-lag bounds (default ``1`` and
        ``4 * lag``); only meaningful with ``committed_error_target``.
      method / options / mesh / batch_axis: forwarded to the underlying
        :class:`~repro.core.Estimator` (same surface as
        :class:`TrajectoryEngine`; ``options=None`` = method defaults in
        the robust ``discrete`` element mode, see
        :func:`repro.serving.waves.robust_default_options`).
      diagnostics: forwarded to the Estimator; the streaming default is
        ``False`` (skip cost/step-norm traces -- latency path).

    API: ``open_track(t0) -> id``; ``push(id, ts_new, y_new)`` merges
    measurements in time order (see the module docstring for late-data
    semantics) and returns the per-category counts; ``step()`` solves one
    wave of due windows; ``run()`` drains; ``estimate(id)`` solves any
    outstanding pushes for that track and returns the stitched committed
    + window :class:`Solution` (``refresh=False`` skips the solve and
    returns the last-solved state); ``window(id)`` / ``committed(id)``
    the parts; ``close(id)`` finalises and removes the track.

    ``open_track``/``push``/``estimate``/``collect``-style readers are
    thread-safe; drive ``step``/``run`` from ONE solver thread while
    clients push concurrently (pushes landing mid-solve simply mark the
    track due again, per-track snapshot sequence numbers keep
    ``estimate``-triggered solves and the solver thread from ever
    applying a stale window result, and a mid-solve merge into the
    about-to-be-evicted region defers that eviction to the re-solve the
    merge queued -- ``stream.deferred_evictions`` -- instead of slicing
    the mutated grid by stale indices).  ``estimate(refresh=True)``
    waits out an in-flight solve of its track, so the result reflects
    every push accepted before the call.
    """

    def __init__(
        self,
        model: Union[LinearSDE, NonlinearSDE],
        *,
        lag: int = 32,
        batch: int = 8,
        method: str = "parallel_rts",
        options=None,
        bucket_sizes: Optional[Sequence[int]] = None,
        mesh=None,
        batch_axis: str = "data",
        diagnostics: bool = False,
        duplicate_policy: str = "error",
        reorder_slack: int = 0,
        max_committed_states: Optional[int] = None,
        committed_error_target: Optional[float] = None,
        lag_min: Optional[int] = None,
        lag_max: Optional[int] = None,
    ):
        if lag < 1:
            raise ValueError(f"lag must be >= 1 interval, got {lag}")
        if options is None:
            # serving default: the robust exact-composition mode -- a
            # streaming window grows without bound between solves, so the
            # length-dependent stability of the euler default is exactly
            # the failure mode to avoid (see robust_default_options).
            options = robust_default_options(method)
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if duplicate_policy not in DUPLICATE_POLICIES:
            raise ValueError(
                f"duplicate_policy must be one of {DUPLICATE_POLICIES}, "
                f"got {duplicate_policy!r}")
        if reorder_slack < 0:
            raise ValueError(
                f"reorder_slack must be >= 0 intervals, got {reorder_slack}")
        if max_committed_states is not None and max_committed_states < 0:
            raise ValueError(
                f"max_committed_states must be >= 0 or None, got "
                f"{max_committed_states}")
        if committed_error_target is None:
            if lag_min is not None or lag_max is not None:
                raise ValueError(
                    "lag_min/lag_max only apply to adaptive lag -- set "
                    "committed_error_target to enable it")
        else:
            if committed_error_target <= 0:
                raise ValueError(
                    f"committed_error_target must be > 0, got "
                    f"{committed_error_target}")
            lag_min = 1 if lag_min is None else lag_min
            lag_max = 4 * lag if lag_max is None else lag_max
            if lag_min < 1:
                raise ValueError(f"lag_min must be >= 1, got {lag_min}")
            if lag_max < lag_min:
                raise ValueError(
                    f"lag_max ({lag_max}) must be >= lag_min ({lag_min})")
            lag = min(max(lag, lag_min), lag_max)
        self.estimator = Estimator(model, method=method, options=options,
                                   mesh=mesh, batch_axis=batch_axis,
                                   diagnostics=diagnostics)
        shard = self.estimator._batch_shard_size(
            self.estimator._resolved_mesh())
        if batch % shard:
            raise ValueError(
                f"batch {batch} not divisible by mesh batch axis size "
                f"{shard}")
        self.model = model
        self.lag = lag
        self.batch = batch
        self.bucket_sizes = bucket_sizes
        self.nonlinear = isinstance(model, NonlinearSDE)
        self.duplicate_policy = duplicate_policy
        self.reorder_slack = reorder_slack
        self.max_committed_states = max_committed_states
        self.committed_error_target = committed_error_target
        self.lag_min = lag_min
        self.lag_max = lag_max
        self.lag_adjustments = 0

        self._lock = threading.Lock()
        # signalled whenever an in-flight wave lands (or fails): lets
        # estimate(refresh=True) wait out a solve that snapshotted the
        # track before the call
        self._cond = threading.Condition(self._lock)
        self._inflight: Dict[int, int] = {}   # track id -> solves in flight
        self._tracks: Dict[int, _Track] = {}
        # track id -> insertion order IS the FIFO due order
        self._due: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._next_id = 0
        self.waves = 0
        self.evicted_intervals = 0

    # -- client surface -----------------------------------------------------

    def open_track(self, t0: float = 0.0) -> int:
        """Open a streaming track whose time grid starts at ``t0``;
        returns the track id used by every other call."""
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            self._tracks[tid] = _Track(float(t0))
            n = len(self._tracks)
        if obs.enabled():
            obs.inc("stream.tracks_opened")
            obs.set_gauge("stream.tracks", n)
        return tid

    def push(self, track_id: int, ts_new, y_new) -> Dict[str, int]:
        """Merge measurements into a track in time order and mark it due.

        ``ts_new`` (``(K,)``, strictly increasing within the batch) are
        grid points anywhere relative to the track: after the last time
        (append), inside the live window (late merge -- the window is
        re-solved with them in place), exactly on an existing point
        (``duplicate_policy`` applies), or at/before the committed
        horizon (dropped + counted).  ``y_new`` is ``(K, ny)``.

        Returns the per-category counts: ``{"appended", "merged",
        "replaced", "dropped_late", "dropped_duplicates"}``.
        """
        ts_new = np.asarray(ts_new, dtype=float)
        y_new = np.asarray(y_new)
        if ts_new.ndim != 1 or ts_new.shape[0] < 1:
            raise ValueError(
                f"ts_new must be (K,) with K >= 1, got shape {ts_new.shape}")
        if y_new.ndim != 2 or y_new.shape[0] != ts_new.shape[0]:
            raise ValueError(
                f"y_new must be (K, ny) = ({ts_new.shape[0]}, ny), got "
                f"shape {y_new.shape}")
        if not np.all(np.diff(ts_new) > 0):
            raise ValueError(
                f"ts_new must be strictly increasing; got {ts_new!r}")
        ny = self.model.ny
        if ny is not None and y_new.shape[1] != ny:
            raise ValueError(
                f"y_new has measurement dimension {y_new.shape[1]} but "
                f"the model's R is {ny}x{ny} (ny={ny})")
        with self._lock:
            track = self._get(track_id)
            if track.y is not None and y_new.shape[1] != track.y.shape[1]:
                raise ValueError(
                    f"y_new has ny={y_new.shape[1]}, track has "
                    f"ny={track.y.shape[1]}")
            res = merge_measurements(track.ts, track.y, ts_new, y_new,
                                     duplicate=self.duplicate_policy)
            track.ts, track.y = res.ts, res.y
            if res.changed:
                track.seq += 1
                if res.merged and track.x_warm is not None:
                    track.x_warm = insert_warm_states(track.x_warm,
                                                      res.positions)
                self._mark_due(track_id, track)
            depth = len(self._due)
        if obs.enabled():
            obs.inc("stream.pushes")
            # accepted intervals only -- drops (late / duplicate-drop)
            # are counted by their own stream.* counters below
            obs.inc("stream.pushed_intervals",
                    res.appended + res.merged + res.replaced)
            obs.set_gauge("stream.queue_depth", depth)
            if res.merged:
                obs.inc("stream.late_merges", res.merged)
            if res.dropped_late:
                obs.inc("stream.late_drops", res.dropped_late)
            if res.replaced:
                obs.inc("stream.duplicates_replaced", res.replaced)
            if res.dropped_duplicates:
                obs.inc("stream.duplicates_dropped", res.dropped_duplicates)
        return {"appended": res.appended, "merged": res.merged,
                "replaced": res.replaced, "dropped_late": res.dropped_late,
                "dropped_duplicates": res.dropped_duplicates}

    def due(self) -> int:
        """Number of tracks with un-solved pushes."""
        return len(self._due)

    def tracks(self) -> List[int]:
        with self._lock:
            return sorted(self._tracks)

    # -- wave processing ----------------------------------------------------

    def step(self) -> int:
        """Solve one wave of due windows; returns windows solved (0 if
        nothing is due).  Snapshots each due track's CURRENT window, so a
        push landing mid-solve marks the track due again for the next
        wave rather than being lost."""
        with self._lock:
            if not self._due:
                return 0
            queue = collections.deque(
                self._snapshot(tid) for tid in self._due)
            wave = take_wave(queue, self.batch)
            for item in wave:
                del self._due[item.key]
                self._inflight[item.key] = \
                    self._inflight.get(item.key, 0) + 1
            depth = len(self._due)
        self._solve_wave(wave, depth)
        return len(wave)

    def _solve_wave(self, wave: List[WaveItem], depth: int) -> None:
        """Solve one snapshotted wave outside the lock and fold the
        results back in.  Always clears the wave's in-flight marks and
        wakes waiting ``estimate(refresh=True)`` callers, even when the
        solve raises."""
        try:
            with obs.trace_span("stream.step"):
                n_pad = wave[0].n_pad
                ts_b, ys_b, mask_b, xi_b, pr_b = pack_wave(wave, self.batch)
                sol = self.estimator.solve(
                    Problem.stacked(self.model, ts_b, ys_b,
                                    measurement_mask=mask_b,
                                    x_init=xi_b, prior=pr_b))
                with self._lock:
                    for row, item in enumerate(wave):
                        self._apply(item, slice_solution(
                            sol, row, item.y.shape[0]))
                    self.waves += 1
                if obs.enabled():
                    record_wave_metrics("stream", wave, n_pad, self.batch,
                                        depth)
                    obs.set_gauge("stream.lag", self.lag)
        finally:
            with self._lock:
                for item in wave:
                    left = self._inflight.pop(item.key, 1) - 1
                    if left > 0:
                        self._inflight[item.key] = left
                self._cond.notify_all()

    def run(self) -> int:
        """Drain every due window; returns total windows solved.  With
        :mod:`repro.obs` enabled sets ``stream.windows_per_sec``."""
        total = 0
        t0 = time.perf_counter()
        with obs.trace_span("stream.run"):
            while self._due:
                total += self.step()
        dt = time.perf_counter() - t0
        if total and dt > 0:
            obs.set_gauge("stream.windows_per_sec", total / dt)
        return total

    # -- estimates ----------------------------------------------------------

    def estimate(self, track_id: int, *, refresh: bool = True) -> Solution:
        """Stitched committed + window estimate: ``x``/``S``/``v`` over
        the track's solved time points (all of them, unless
        ``max_committed_states`` trimmed old history -- then the retained
        suffix).

        By default the estimate is FRESH: every push accepted before
        this call is reflected in the result.  A track with un-solved
        pushes is solved on demand first (a single-track wave), and if a
        ``step()``/``run()`` solve of this track is already in flight
        the call WAITS for it to land before re-checking -- a push that
        arrived mid-solve triggers the on-demand solve; whichever solve
        lands first wins and the other is discarded by the snapshot
        sequence check.  ``refresh=False`` returns the last-solved state
        as-is, which silently EXCLUDES any newer or in-flight pushes --
        the fast read for dashboards that poll while a solver thread
        drains.

        ``S``/``v`` are the forward-filter information at each point (the
        quantity the window handoff chains on).
        """
        if refresh:
            self._refresh(track_id)
        with self._lock:
            track = self._get(track_id)
            if track.win_x is None:
                raise ValueError(
                    f"track {track_id} has no estimate yet -- push "
                    "measurements and call step()/run() first")
            return Solution(
                x=np.concatenate(track.committed_x + [track.win_x]),
                S=np.concatenate(track.committed_S + [track.win_S]),
                v=np.concatenate(track.committed_v + [track.win_v]),
                cost=track.last_cost)

    def _refresh(self, track_id: int) -> None:
        """Make ``track_id``'s estimate fresh: solve its window now if
        it has un-solved pushes (one single-track wave, off the FIFO),
        first waiting out any ``step()``/``run()`` solve of this track
        already in flight -- a mid-solve track is no longer in the due
        set, but its result has not landed either, so returning without
        waiting would silently exclude those pushes."""
        with self._lock:
            while True:
                self._get(track_id)
                if track_id in self._due:
                    item = self._snapshot(track_id)
                    del self._due[track_id]
                    self._inflight[track_id] = \
                        self._inflight.get(track_id, 0) + 1
                    depth = len(self._due)
                    break
                if not self._inflight.get(track_id):
                    return                 # nothing un-solved or in flight
                # snapshotted by a solver thread: wait for that wave to
                # land, then re-check (a push may have arrived mid-solve
                # and marked the track due again)
                self._cond.wait()
        if obs.enabled():
            obs.inc("stream.refresh_solves")
        self._solve_wave([item], depth)

    def window(self, track_id: int) -> Solution:
        """The live window's estimate alone (last solve; ``lag + 1`` states
        once the track is past its lag)."""
        with self._lock:
            track = self._get(track_id)
            if track.win_x is None:
                raise ValueError(
                    f"track {track_id} has no estimate yet -- push "
                    "measurements and call step()/run() first")
            return Solution(x=track.win_x, S=track.win_S, v=track.win_v)

    def committed(self, track_id: int) -> Optional[Solution]:
        """The evicted (finalised) history as a Solution segment, or
        ``None`` if nothing has been evicted yet.  Committed states are
        never re-solved; with ``max_committed_states`` set this is the
        RETAINED suffix (the oldest states past the cap are gone --
        ``stream.committed_trimmed`` counts them)."""
        with self._lock:
            track = self._get(track_id)
            if not track.committed_x:
                return None
            return Solution(x=np.concatenate(track.committed_x),
                            S=np.concatenate(track.committed_S),
                            v=np.concatenate(track.committed_v))

    def close(self, track_id: int) -> Solution:
        """Finalise a track: solve any outstanding pushes, return the full
        stitched estimate (the retained suffix under
        ``max_committed_states``), and drop the track's state."""
        final = self.estimate(track_id)
        with self._lock:
            del self._tracks[track_id]
            self._due.pop(track_id, None)
            n = len(self._tracks)
        if obs.enabled():
            obs.inc("stream.tracks_closed")
            obs.set_gauge("stream.tracks", n)
        return final

    # -- internals ----------------------------------------------------------

    def _get(self, track_id: int) -> _Track:
        try:
            return self._tracks[track_id]
        except KeyError:
            raise KeyError(
                f"unknown track id {track_id} (open tracks: "
                f"{sorted(self._tracks)})") from None

    def _mark_due(self, track_id: int, track: _Track) -> None:
        """Add a track to the due set (caller holds lock), stamping
        ``due_since`` only on the transition so the latency histogram
        measures first-unsolved-change to solved."""
        if track_id not in self._due:
            track.due_since = time.perf_counter()
            self._due[track_id] = None

    def _snapshot(self, tid: int) -> WaveItem:
        """WaveItem for a due track's current window (caller holds lock).
        Arrays are never mutated in place (pushes re-concatenate), so the
        references stay valid while the solve runs outside the lock."""
        track = self._tracks[tid]
        n_pad = bucket_length(track.y.shape[0], self.estimator.block_size,
                              self.bucket_sizes)
        x_init = None
        if self.nonlinear:
            # uniform warm start across the wave: re-solves continue from
            # the previous window trajectory, fresh windows from the prior
            # mean (= iterated_solve's own default)
            if track.x_warm is not None:
                x_init = track.x_warm
            elif track.prior is None:
                x_init = np.broadcast_to(
                    np.asarray(self.model.m0),
                    (track.y.shape[0] + 1,) + np.shape(self.model.m0))
            else:
                mean = np.linalg.solve(track.prior[0], track.prior[1])
                x_init = np.broadcast_to(
                    mean, (track.y.shape[0] + 1,) + mean.shape)
        return WaveItem(tid, track.ts, track.y, n_pad, track.due_since,
                        x_init=x_init, prior=track.prior,
                        seq=track.seq, base=track.offset)

    def _apply(self, item: WaveItem, sol: Solution) -> None:
        """Fold one window solution back into its track (caller holds
        lock): store the window estimate, evict past the lag (+ reorder
        slack), advance the boundary prior and warm start, steer the
        adaptive lag.

        Solve results may land out of order when an ``estimate()``
        refresh races the solver thread: a result older than the last
        applied snapshot (``seq``) is discarded, and a newer result whose
        snapshot predates an eviction is re-based via ``item.base`` so it
        never double-commits states.

        A push landing WHILE this solve was in flight (``track.seq !=
        item.seq``) may also have mutated the grid itself.  Eviction
        slices ``track.ts``/``track.y`` by snapshot index, so it only
        proceeds if the to-be-evicted region of the CURRENT grid still
        matches the snapshot (mid-solve appends, and merges/replaces past
        the boundary, keep it intact); a merge or replace inside that
        region would make the slice drop the wrong points -- and the
        snapshot solve never saw that data anyway -- so eviction is
        deferred to the re-solve the mutating push already queued
        (``stream.deferred_evictions``)."""
        track = self._tracks.get(item.key)
        if track is None:                      # closed mid-solve
            return
        if item.seq <= track.applied_seq:      # a newer solve already landed
            return
        track.applied_seq = item.seq
        n = item.y.shape[0]                    # window intervals at snapshot
        x = np.asarray(sol.x)
        S = np.asarray(sol.S)
        v = np.asarray(sol.v)
        # x[i] is the state at absolute interval item.base + i; `shift`
        # intervals of the snapshot were already committed by an apply
        # that raced ahead of this one.
        shift = track.offset - item.base
        keep = self.lag + self.reorder_slack
        evict = max(0, (item.base + max(0, n - keep)) - track.offset)
        if evict and track.seq != item.seq and \
                not self._evict_region_unchanged(track, item, shift, evict):
            evict = 0
            if obs.enabled():
                obs.inc("stream.deferred_evictions")
        if evict:
            self._observe_eviction(track, x[shift:shift + evict],
                                   item.ts[shift:shift + evict])
            track.committed_x.append(x[shift:shift + evict])
            track.committed_S.append(S[shift:shift + evict])
            track.committed_v.append(v[shift:shift + evict])
            track.prior = (S[shift + evict].copy(), v[shift + evict].copy())
            track.ts = track.ts[evict:]
            track.y = track.y[evict:]
            track.offset += evict
            self.evicted_intervals += evict
            self._trim_committed(track)
            if obs.enabled():
                obs.inc("stream.evicted_intervals", evict)
        track.win_x, track.win_S, track.win_v = \
            x[shift + evict:], S[shift + evict:], v[shift + evict:]
        track.win_ts = item.ts[shift + evict:]
        if self.nonlinear:
            x_warm = x[shift + evict:]
            if track.seq != item.seq:
                # mid-solve pushes mutated the grid: re-align the warm
                # start onto it (a misaligned hint would hand the next
                # iterated solve neighbouring states at every point past
                # the first insertion)
                x_warm = _zoh_resample(x_warm, item.ts[shift + evict:],
                                       track.ts)
            track.x_warm = x_warm
        else:
            track.x_warm = None
        track.solves += 1
        if sol.cost is not None:
            track.last_cost = float(sol.cost)

    def _evict_region_unchanged(self, track: _Track, item: WaveItem,
                                shift: int, evict: int) -> bool:
        """True when the current grid still matches ``item``'s snapshot
        over the to-be-evicted region -- the first ``evict + 1`` grid
        points (boundary included) and their measurements -- so slicing
        ``track.ts``/``track.y`` by snapshot index is safe even though
        the track mutated mid-solve (caller holds lock)."""
        m = evict + 1
        return (track.ts.shape[0] >= m
                and bool(np.array_equal(track.ts[:m],
                                        item.ts[shift:shift + m]))
                and bool(np.array_equal(track.y[:evict],
                                        item.y[shift:shift + evict])))

    def _observe_eviction(self, track: _Track, evicted_x: np.ndarray,
                          evicted_ts: np.ndarray) -> None:
        """Measure the smoothing residual of the states about to be
        committed -- how much their estimate still changed between the
        previous solve and this (final) one -- and steer the adaptive lag
        (caller holds lock).

        Rows are matched by TIMESTAMP against the previous window
        (``win_ts``): a late measurement merged since that solve shifts
        positions, so positional alignment would difference states at
        DIFFERENT time points.  Points with no previous estimate (just
        merged) carry no residual signal and are skipped.  No previous
        window (first solve) = no signal.
        """
        if track.win_x is None:
            return
        prev_ts, prev_x = track.win_ts, track.win_x
        idx = np.searchsorted(prev_ts, evicted_ts)
        found = idx < prev_ts.shape[0]
        found &= prev_ts[np.minimum(idx, prev_ts.shape[0] - 1)] == evicted_ts
        if not found.any():
            return
        delta = float(np.max(np.abs(evicted_x[found] - prev_x[idx[found]])))
        track.last_evict_delta = delta
        if obs.enabled():
            obs.record("stream.evict_delta", delta)
        target = self.committed_error_target
        if target is None:
            return
        old = self.lag
        if delta > target:
            self.lag = min(self.lag_max, self.lag + 1)
        elif delta < target * _LAG_SHRINK_RATIO:
            self.lag = max(self.lag_min, self.lag - 1)
        if self.lag != old:
            self.lag_adjustments += 1
            if obs.enabled():
                obs.inc("stream.lag_adjustments")
                obs.set_gauge("stream.lag", self.lag)

    def _trim_committed(self, track: _Track) -> None:
        """Enforce ``max_committed_states``: drop the OLDEST committed
        states past the cap (caller holds lock)."""
        cap = self.max_committed_states
        if cap is None:
            return
        excess = sum(a.shape[0] for a in track.committed_x) - cap
        if excess <= 0:
            return
        track.trimmed += excess
        if obs.enabled():
            obs.inc("stream.committed_trimmed", excess)
        while excess > 0:
            head = track.committed_x[0].shape[0]
            if head <= excess:
                del track.committed_x[0]
                del track.committed_S[0]
                del track.committed_v[0]
                excess -= head
            else:
                track.committed_x[0] = track.committed_x[0][excess:]
                track.committed_S[0] = track.committed_S[0][excess:]
                track.committed_v[0] = track.committed_v[0][excess:]
                excess = 0
