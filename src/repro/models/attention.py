"""Attention mixer: GQA, RoPE, qk-norm, sliding window, chunked streaming.

Two execution paths:

* ``chunked_mha`` -- pure-JAX streaming-softmax attention (double scan over
  q/kv chunks).  Never materialises the (L, L) logits, so 32k-sequence
  cells compile within the per-device HBM budget.  This is the path the
  multi-pod dry-run lowers (the CPU backend cannot lower Mosaic kernels);
  on TPU the Pallas ``repro.kernels.flash_attention`` kernel replaces it
  via ``use_kernel=True``.
* decode path -- single-token attention against a (possibly rolling) KV
  cache; O(L) work, no chunking needed.

GQA is computed WITHOUT repeating K/V: q is reshaped to
(B, Hkv, rep, L, D) and contracted group-wise.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import logical_constraint

from .layers import P, apply_rope, rms_norm, rope_freqs

_NEG = -1e30


def attn_spec(cfg: ModelConfig) -> dict:
    D, Hq, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    kv_tail = None if cfg.kv_replicate else "head"
    spec = {
        "wq": P((D, Hq, hd), ("embed", "heads", "head")),
        "wk": P((D, Hkv, hd), ("embed", "kv_heads", kv_tail)),
        "wv": P((D, Hkv, hd), ("embed", "kv_heads", kv_tail)),
        "wo": P((Hq, hd, D), ("heads", "head", "embed"), fan_in=Hq * hd),
    }
    if cfg.qk_norm:
        spec["q_norm"] = P((hd,), ("head",), init="ones")
        spec["k_norm"] = P((hd,), ("head",), init="ones")
    return spec


class KVCache(NamedTuple):
    """Dense or rolling-window KV cache for one layer.

    k, v: (B, Hkv, W, hd) where W = window or max context; ``pos`` is the
    number of tokens already absorbed (same for every batch row under the
    continuous-batching engine's padding discipline).
    """
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray   # () int32


def chunked_mha(q, k, v, *, causal: bool, window: Optional[int],
                chunk_q: int = 512, chunk_k: int = 512,
                causal_skip: bool = False):
    """Streaming-softmax attention, (B, Hq, Lq, D) x (B, Hkv, Lk, D).

    ``causal_skip=True`` enables the triangular schedule: strictly-upper
    kv chunks are skipped entirely (halves the logit FLOPs for causal
    self-attention; used by the perf-optimised path, see EXPERIMENTS.md
    SPerf).
    """
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = D ** -0.5
    cq = min(chunk_q, Lq)
    ck = min(chunk_k, Lk)
    assert Lq % cq == 0 and Lk % ck == 0, (Lq, cq, Lk, ck)
    nq, nk = Lq // cq, Lk // ck
    off = Lk - Lq  # q rows aligned to the end of the keys

    qg = q.reshape(B, Hkv, rep, Lq, D)

    def q_block(qi, qc):
        # qc: (B, Hkv, rep, cq, D)
        m0 = jnp.full((B, Hkv, rep, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, cq, D), jnp.float32)

        def kv_step(carry, kj):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, kj * ck, ck, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(v, kj * ck, ck, axis=2)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            rows = off + qi * cq + jnp.arange(cq)[:, None]
            cols = kj * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= rows >= cols
            if window is not None:
                mask &= (rows - cols) < window
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        if causal and causal_skip:
            # only kv chunks intersecting the causal band of this q chunk
            hi = (off + (qi + 1) * cq + ck - 1) // ck
            lo = 0
            if window is not None:
                lo = jnp.maximum(
                    0, (off + qi * cq - (window - 1)) // ck)
                # dynamic lo needs a static-length scan; fall back to hi-only
                lo = 0
            length = nk  # static upper bound
            idx = jnp.arange(length)

            def guarded(carry, kj):
                do = kj < hi
                new, _ = kv_step(carry, jnp.minimum(kj, nk - 1))
                out = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(do, a, b), new, carry)
                return out, None

            (m, l, acc), _ = jax.lax.scan(guarded, (m0, l0, a0), idx)
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    # flash-style backward: recompute each q-chunk's kv sweep instead of
    # storing (nq, nk, ...) probability tiles (multi-GB at 4k+ contexts)
    q_block_ckpt = jax.checkpoint(q_block, static_argnums=())

    def scan_q(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * cq, cq, axis=3)
        return None, q_block_ckpt(qi, qc)

    _, blocks = jax.lax.scan(scan_q, None, jnp.arange(nq))
    # blocks: (nq, B, Hkv, rep, cq, D)
    out = jnp.moveaxis(blocks, 0, 3).reshape(B, Hkv, rep, Lq, D)
    return out.reshape(B, Hq, Lq, D)


def attention_forward(params, x, cfg: ModelConfig, positions, *,
                      use_kernel: bool = False, interpret: bool = False,
                      causal_skip: bool = False):
    """Full-sequence attention (train / prefill).  x: (B, L, D)."""
    B, L, D = x.shape
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(positions, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos[:, None], sin[:, None])
    k = apply_rope(k, cos[:, None], sin[:, None])
    q = logical_constraint(q.transpose(0, 2, 1, 3),
                           "batch", "heads", None, None)
    k = logical_constraint(k.transpose(0, 2, 1, 3),
                           "batch", "kv_heads", None, None)
    v = logical_constraint(v.transpose(0, 2, 1, 3),
                           "batch", "kv_heads", None, None)
    causal = cfg.causal and not cfg.is_encoder
    if use_kernel:
        from repro.kernels.flash_attention import attention_trainable
        o = attention_trainable(q, k, v, causal, cfg.window, interpret)
    else:
        o = chunked_mha(q, k, v, causal=causal, window=cfg.window,
                        causal_skip=causal_skip)
    o = o.transpose(0, 2, 1, 3)  # (B, L, Hq, hd)
    out = jnp.einsum("blhk,hkd->bld", o, params["wo"])
    return logical_constraint(out, "batch", None, None)


def attention_decode(params, x, cfg: ModelConfig, cache: KVCache):
    """One-token attention against the cache.  x: (B, 1, D)."""
    B = x.shape[0]
    W = cache.k.shape[2]
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k_new = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v_new = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, params["k_norm"], cfg.norm_eps)
    pos = cache.pos
    cos, sin = rope_freqs(pos[None].astype(jnp.float32), cfg.hd,
                          cfg.rope_theta)
    q = apply_rope(q, cos[:, None], sin[:, None])
    k_new = apply_rope(k_new, cos[:, None], sin[:, None])

    slot = pos % W if cfg.window is not None else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.transpose(0, 2, 1, 3), slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.transpose(0, 2, 1, 3), slot, axis=2)

    qh = q.transpose(0, 2, 1, 3)   # (B, Hq, 1, hd)
    rep = cfg.num_heads // cfg.num_kv_heads
    qg = qh.reshape(B, cfg.num_kv_heads, rep, 1, cfg.hd)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * (cfg.hd ** -0.5)
    idx = jnp.arange(W)
    if cfg.window is None:
        valid = idx <= pos
    else:
        # rolling cache: slot s holds position pos - ((pos%W - s) mod W)
        age = jnp.mod(pos % W - idx, W)
        valid = age <= pos
    s = jnp.where(valid.reshape(1, 1, 1, 1, W), s, _NEG)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v_cache.dtype), v_cache)
    o = o.reshape(B, cfg.num_heads, 1, cfg.hd).transpose(0, 2, 1, 3)
    out = jnp.einsum("blhk,hkd->bld", o, params["wo"])
    return out, KVCache(k_cache, v_cache, pos + 1)


def _unrolled_positions(idx, pos, W):
    """True token position stored in each rolling-cache slot."""
    cur_slot = pos % W
    # slot s holds position: pos - ((cur_slot - s) mod W)
    return pos - jnp.mod(cur_slot - idx, W)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    W = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, cfg.num_kv_heads, W, cfg.hd)
    return KVCache(
        jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
        jnp.zeros((), jnp.int32))
