"""Snapshot + benchmark-artifact export (the ``BENCH_<name>.json`` files).

``snapshot()`` is the one-call readout of everything recorded: counters,
gauges, histogram summaries (p50/p90/p99) and, optionally, the recent
span trees.

``bench_record``/``write_bench_json`` produce the schema-versioned
benchmark artifact emitted by ``benchmarks/run.py --json`` and diffed by
``benchmarks/compare.py`` in CI (``docs/OBSERVABILITY.md`` documents the
schema).  Every record carries the RNG seeds used and an environment
fingerprint (device, jax versions, ``XLA_FLAGS``, x64 policy) so a
number is never detached from the machine state that produced it.
"""
from __future__ import annotations

import json
import os
import platform
from typing import Dict, List, Optional, Sequence

from . import metrics, tracing

SCHEMA_VERSION = 1

# every benchmark row must carry exactly these (run.py's CSV columns)
ROW_KEYS = ("name", "us_per_call", "derived")


def snapshot(include_trees: bool = False) -> dict:
    """Everything recorded so far: ``{"enabled", "counters", "gauges",
    "histograms", "dropped_records"[, "span_trees"]}``."""
    out = {"enabled": metrics.enabled()}
    out.update(metrics.REGISTRY.snapshot())
    if include_trees:
        out["span_trees"] = tracing.span_trees()
    return out


def env_fingerprint() -> dict:
    """Machine/runtime state a benchmark number depends on.  ``jax`` is
    imported lazily; fields degrade to ``None`` without it."""
    fp = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "jax_platforms": os.environ.get("JAX_PLATFORMS", ""),
    }
    try:
        import jax
        devs = jax.devices()
        fp.update({
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": devs[0].device_kind if devs else None,
            "device_count": len(devs),
            "x64": bool(jax.config.jax_enable_x64),
        })
    except Exception:
        fp.update({"jax": None, "backend": None, "device_kind": None,
                   "device_count": None, "x64": None})
    return fp


def bench_record(name: str, rows: Sequence[Dict],
                 seeds: Optional[Dict[str, int]] = None,
                 obs_snapshot: Optional[dict] = None) -> dict:
    """Assemble a schema-v1 benchmark artifact from harness rows."""
    rows = [
        {"name": str(r["name"]),
         "us_per_call": float(r["us_per_call"]),
         "derived": str(r["derived"])}
        for r in rows
    ]
    return {
        "schema_version": SCHEMA_VERSION,
        "benchmark": name,
        "seeds": dict(seeds or {}),
        "env": env_fingerprint(),
        "rows": rows,
        "obs": snapshot() if obs_snapshot is None else obs_snapshot,
    }


def validate_bench(record: dict) -> List[str]:
    """Schema-check a benchmark record; returns a list of problems
    (empty == valid).  Kept in sync with ``docs/OBSERVABILITY.md``."""
    problems: List[str] = []
    if not isinstance(record, dict):
        return ["record is not a JSON object"]
    if record.get("schema_version") != SCHEMA_VERSION:
        problems.append(
            f"schema_version must be {SCHEMA_VERSION}, "
            f"got {record.get('schema_version')!r}")
    for key, typ in (("benchmark", str), ("seeds", dict), ("env", dict),
                     ("rows", list), ("obs", dict)):
        if not isinstance(record.get(key), typ):
            problems.append(f"missing or mistyped field {key!r} "
                            f"(want {typ.__name__})")
    for i, row in enumerate(record.get("rows") or []):
        if not isinstance(row, dict):
            problems.append(f"rows[{i}] is not an object")
            continue
        for k in ROW_KEYS:
            if k not in row:
                problems.append(f"rows[{i}] missing {k!r}")
        if not isinstance(row.get("us_per_call", 0.0), (int, float)):
            problems.append(f"rows[{i}].us_per_call is not a number")
    obs = record.get("obs")
    if isinstance(obs, dict):
        for key in ("counters", "gauges", "histograms"):
            if not isinstance(obs.get(key), dict):
                problems.append(f"obs.{key} missing or mistyped")
    return problems


def write_bench_json(path: str, record: dict) -> str:
    """Validate and write a benchmark artifact; returns ``path``."""
    problems = validate_bench(record)
    if problems:
        raise ValueError("invalid benchmark record: " + "; ".join(problems))
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
