"""Pallas TPU kernel: chunked SSD scan (mamba2), TPU-tiled.

This is the TPU-native realisation of the paper's block-element + scan
decomposition (DESIGN.md S3): the sequence is split into chunks of Q steps;
each chunk reduces to an "element" (scalar decay, (P, S) state increment) =
the affine element (Phi, beta) of eqs. (45)-(46) with diagonal Phi, and the
inter-chunk recurrence folds elements left-to-right while the intra-chunk
part is a dense (Q, Q) masked matmul that feeds the MXU.

Grid: (batch*heads, num_chunks) with the chunk dimension ARBITRARY
(sequential) -- the running (P, S) state lives in a VMEM scratch buffer and
is carried across grid steps, exactly the blocked-scan pattern.  Block
shapes are MXU-aligned for P, S, Q multiples of 128 (Q=chunk len) and fall
back gracefully for smaller test shapes.

VMEM budget per step (f32): x(Q P) + B,C(Q S) + state(P S) + mask(Q Q)
~ 128*128*6*4B ~ 0.4 MiB for Q=P=S=128: far under the ~16 MiB VMEM limit,
leaving headroom for double buffering of the HBM->VMEM pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _ssd_kernel(l_ref, dtx_ref, B_ref, C_ref, y_ref, state, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    l = l_ref[0]            # (Q,)  per-step log decay (dt * A), <= 0
    dtx = dtx_ref[0]        # (Q, P) dt-weighted inputs
    Bm = B_ref[0]           # (Q, S)
    Cm = C_ref[0]           # (Q, S)

    cum = jnp.cumsum(l)                         # (Q,)
    total = cum[-1]

    # inter-chunk contribution: y_t += exp(cum_t) * C_t . state
    carry_in = state[...]                        # (P, S)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, carry_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q, P)

    # intra-chunk: masked decay kernel  M[t,s] = exp(cum_t - cum_s) [s<=t]
    ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jds = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ids >= jds
    logdecay = cum[:, None] - cum[None, :]
    M = jnp.where(causal, jnp.exp(logdecay), 0.0)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jax.lax.dot_general(M * G, dtx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)

    # element fold (eqs. 45-46, diagonal Phi): state' = e^total * state + inc
    w = jnp.exp(total - cum)[:, None] * dtx      # (Q, P)
    inc = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, S)
    state[...] = jnp.exp(total) * carry_in + inc


def ssd_chunked(l, dtx, B, C, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.

    Args:
      l:   (BH, L)     log decays dt*A (<= 0)
      dtx: (BH, L, P)  dt-weighted inputs
      B:   (BH, L, S)
      C:   (BH, L, S)
    Returns:
      y: (BH, L, P)
    """
    BH, L, P = dtx.shape
    S = B.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    f32 = jnp.float32
    grid = (BH, nc)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, chunk, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, S), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, S), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, P), dtx.dtype),
        scratch_shapes=[pltpu.VMEM((P, S), f32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(l, dtx, B, C)
    return y
