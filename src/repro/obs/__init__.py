"""``repro.obs`` -- the telemetry spine: metrics, tracing, export.

Process-local observability every hot path reports through (taxonomy and
JSON schema in ``docs/OBSERVABILITY.md``):

    import repro.obs as obs

    obs.enable()                      # default off; REPRO_OBS=1 also works
    with obs.trace_span("estimator.solve"):
        ...
    obs.inc("cache.hits")
    obs.record("engine.record_latency_seconds", dt)
    obs.snapshot()                    # -> dict (counters/gauges/histograms)

Disabled (the default) every helper is a no-op that allocates nothing, so
instrumented hot paths cost one bool check.  Values that refuse ``float``
concretisation (JAX tracers reaching instrumentation under ``jit``) are
dropped, never captured.  ``benchmarks/run.py --json`` serialises
``snapshot()`` plus seeds and an environment fingerprint into the
schema-versioned ``BENCH_<name>.json`` artifacts that
``benchmarks/compare.py`` gates in CI.
"""
from .export import (
    ROW_KEYS,
    SCHEMA_VERSION,
    bench_record,
    env_fingerprint,
    snapshot,
    validate_bench,
    write_bench_json,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    REGISTRY,
    counter,
    disable,
    enable,
    enabled,
    gauge,
    histogram,
    inc,
    record,
    set_gauge,
)
from .tracing import Span, span_trees, trace_span, xla_profile
from . import metrics as _metrics, tracing as _tracing


def reset() -> None:
    """Clear every recorded metric and span (keeps the enabled flag)."""
    _metrics.reset()
    _tracing.reset()


__all__ = [
    "enable", "disable", "enabled", "reset",
    "counter", "gauge", "histogram", "inc", "record", "set_gauge",
    "Counter", "Gauge", "Histogram", "REGISTRY",
    "trace_span", "span_trees", "xla_profile", "Span",
    "snapshot", "env_fingerprint",
    "bench_record", "validate_bench", "write_bench_json",
    "SCHEMA_VERSION", "ROW_KEYS",
]
