"""Kernel micro-benchmarks (reference paths on CPU; the Pallas kernels
target TPU and are correctness-validated in interpret mode -- interpret
timing is not meaningful, so this times the jnp reference lowering and
reports the kernel's analytic VMEM/arithmetic profile as `derived`)."""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, repeats=5):
    out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / repeats * 1e6


def _rand_lqt_elems(rng, B, nx):
    """Batched random LQT elements (PSD C/J), f32 — shared by the
    lqt_combine and lqt_scan benchmark sections."""
    from repro.core.types import LQTElement

    def psd():
        A = rng.standard_normal((B, nx, nx))
        return jnp.asarray(
            np.einsum("bij,bkj->bik", A, A) / nx + 0.1 * np.eye(nx),
            jnp.float32)

    return LQTElement(
        jnp.asarray(rng.standard_normal((B, nx, nx)) * 0.6, jnp.float32),
        jnp.asarray(rng.standard_normal((B, nx)), jnp.float32),
        psd(),
        jnp.asarray(rng.standard_normal((B, nx)), jnp.float32), psd())


def run(smoke=False):
    rows = []
    rng = np.random.default_rng(0)

    # lqt_combine: batched eq. (42)
    from repro.core.combine import lqt_combine
    for B, nx in [(64, 4)] if smoke else [(1024, 4), (4096, 4), (1024, 8)]:
        e1 = _rand_lqt_elems(rng, B, nx)
        us = _time(jax.jit(lqt_combine), e1, e1)
        flops = B * (2 * nx ** 3 * 8)  # ~8 small matmuls + solve
        rows.append({
            "name": f"kern/lqt_combine/B{B}_nx{nx}",
            "us_per_call": us,
            "derived": f"gflops={flops / us / 1e3:.2f}",
        })

    # lqt whole-scan (jnp path; the parallel_kernel method replaces this
    # suffix scan with the lane-major Pallas multi-level scan -- same
    # combine tree, so level count and per-level lane batches transfer)
    from repro.kernels.lqt_combine import lqt_scan_ref
    for T, nx in [(64, 4)] if smoke else [(1024, 4), (4096, 4), (1024, 8)]:
        elems = _rand_lqt_elems(rng, T, nx)
        fn = jax.jit(lambda e: lqt_scan_ref(e, reverse=True))
        us = _time(fn, elems)
        levels = max(1, int(np.ceil(np.log2(T))))
        rows.append({
            "name": f"kern/lqt_scan/T{T}_nx{nx}",
            "us_per_call": us,
            "derived": f"levels={levels},elems_per_s={T / (us / 1e6):.0f}",
        })

    # ssd chunked scan (jnp path; == kernel algorithm)
    from repro.models.ssm import ssd_scan_jnp
    for (b, L, H, P, S, Q) in ([(1, 256, 4, 16, 16, 64)] if smoke
                               else [(2, 2048, 8, 64, 64, 128)]):
        x = jnp.asarray(rng.standard_normal((b, L, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, L, H)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 1.0, (H,)), jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((b, L, 1, S)), jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((b, L, 1, S)), jnp.float32)
        D = jnp.ones((H,), jnp.float32)
        fn = jax.jit(lambda *a: ssd_scan_jnp(*a, chunk=Q))
        us = _time(fn, x, dt, A, Bm, Cm, D)
        toks = b * L
        rows.append({
            "name": f"kern/ssd/b{b}_L{L}_H{H}_P{P}_S{S}",
            "us_per_call": us,
            "derived": f"tokens_per_s={toks / (us / 1e6):.0f}",
        })

    # chunked attention (ref path of the flash kernel)
    from repro.models.attention import chunked_mha
    for (b, Hq, Hkv, L, D, ck) in ([(1, 2, 1, 256, 32, 128)] if smoke
                                   else [(1, 8, 2, 2048, 64, 256)]):
        q = jnp.asarray(rng.standard_normal((b, Hq, L, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, Hkv, L, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, Hkv, L, D)), jnp.float32)
        fn = jax.jit(lambda q, k, v: chunked_mha(
            q, k, v, causal=True, window=None, chunk_q=ck, chunk_k=ck))
        us = _time(fn, q, k, v)
        fl = 4 * b * Hq * L * L * D
        rows.append({
            "name": f"kern/attn/b{b}_H{Hq}_L{L}",
            "us_per_call": us,
            "derived": f"gflops={fl / us / 1e3:.1f}",
        })
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write a BENCH json artifact for this section")
    args = ap.parse_args()
    import repro.obs as obs
    if args.json:
        obs.enable()
        obs.reset()
    rows = run(smoke=args.smoke)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        obs.write_bench_json(
            args.json, obs.bench_record("kern", rows, seeds={"kern": 0}))


if __name__ == "__main__":
    main()
