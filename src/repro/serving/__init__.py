from .engine import Request, ServeEngine
from .streaming import StreamingEngine
from .trajectory import TrajectoryEngine

__all__ = ["Request", "ServeEngine", "StreamingEngine", "TrajectoryEngine"]
