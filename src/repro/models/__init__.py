"""LM model zoo: one generic stack covering all assigned architectures."""
from . import attention, layers, moe, ssm, transformer
from .transformer import (
    axes, decode_step, init, init_caches, prefill, shapes, train_loss,
)

__all__ = [
    "attention", "layers", "moe", "ssm", "transformer",
    "axes", "decode_step", "init", "init_caches", "prefill", "shapes",
    "train_loss",
]
