"""Parallel-in-time MAP estimation (the paper's contribution, sections 3-4).

Pipeline (all reversed-time; results are flipped back to original time):

1. **Element init** (parallel over blocks): eq. (43) Euler integration
   (``euler`` mode) or exact substep-element composition (``discrete``).
2. **Backward pass**: suffix associative scan with the combine (42) over
   ``[a_0 .. a_{T-1}, a_T]`` -> value functions S(tau_i), v(tau_i) at all
   block boundaries = parallel Kalman-Bucy filter, section 4 (log-span).
3. **Interior fill** (parallel over blocks): backward HJB/(15) within each
   block from its right-boundary value.
4. **Recovery**:
   * method 1 (parallel RTS smoother, section 4.3): per-substep affine maps
     -> within-block compose -> prefix scan with (45)-(46) -> eq. (47);
   * method 2 (parallel two-filter smoother): prefix scan of
     ``[e (x) a_0, a_1, ...]`` (eqs. 49-50) -> eq. (48), forward HJB (51)
     interior fill, plus smoothing covariances (beyond-paper extra).

Every stage is either an associative scan or an embarrassingly parallel
vmap over blocks; ``scan_fn`` lets callers swap the on-chip scan for the
distributed multi-chip scan (``core.pscan.distributed_scan``) or a kernel-
backed combine (``repro.kernels.lqt_combine``).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import pscan
from .combine import affine_combine, elem_min_initial, lqt_combine
from .elements import (
    backward_value_fill_discrete,
    backward_value_fill_euler,
    discrete_block_elements,
    euler_block_elements,
    forward_value_fill_discrete,
    forward_value_fill_euler,
    identity_element,
    one_step_elements,
    terminal_element,
)
from .sequential import affine_recovery_maps, two_filter_combine
from .types import AffineElement, GridLQT, LQTElement, MAPSolution, ValueFn


def _append_elem(elems: LQTElement, last: LQTElement) -> LQTElement:
    return jax.tree_util.tree_map(
        lambda a, l: jnp.concatenate([a, l[None]], axis=0), elems, last)


def _prepend_elem(first: LQTElement, elems: LQTElement) -> LQTElement:
    return jax.tree_util.tree_map(
        lambda f, a: jnp.concatenate([f[None], a], axis=0), first, elems)


def parallel_backward(
    grid: GridLQT,
    nsub: int,
    mode: str = "euler",
    combine_fn: Callable = lqt_combine,
    suffix_scan_fn: Optional[Callable] = None,
):
    """Parallel Kalman-Bucy filter (information form).

    Returns ``(values_full, boundary, block_elems, sub_elems)`` where
    ``values_full`` holds S(tau_j), v(tau_j) for every substep j = 0..N,
    ``boundary`` the block-boundary values (T+1, ...), ``block_elems`` the
    scan elements, and ``sub_elems`` the per-substep elements (``discrete``
    mode only, else None).
    """
    if mode == "discrete":
        blocks, sub = discrete_block_elements(grid, nsub)
    elif mode in ("euler", "rk4"):
        blocks = euler_block_elements(grid, nsub, integrator=mode)
        sub = None
    else:
        raise ValueError(f"unknown element mode: {mode}")

    elems = _append_elem(blocks, terminal_element(grid))
    if suffix_scan_fn is not None:
        sbar = suffix_scan_fn(elems)
    else:
        sbar = pscan.suffix_scan(combine_fn, elems)
    boundary = ValueFn(sbar.J, sbar.eta)                      # (T+1, ...)

    right = ValueFn(boundary.S[1:], boundary.v[1:])           # (T, ...)
    if mode == "discrete":
        interior = backward_value_fill_discrete(sub, right)   # (T, n, ...)
    else:
        interior = backward_value_fill_euler(grid, nsub, right,
                                             integrator=mode)

    # Replace each block's left point with the scan-combined boundary value
    # (identical in discrete mode; the parallel-consistent choice in euler
    # mode), then flatten to the full (N+1) substep grid.
    S_blk = interior.S.at[:, 0].set(boundary.S[:-1])
    v_blk = interior.v.at[:, 0].set(boundary.v[:-1])
    N = grid.N
    values_full = ValueFn(
        jnp.concatenate(
            [S_blk.reshape((N,) + S_blk.shape[2:]), boundary.S[-1:]], axis=0),
        jnp.concatenate(
            [v_blk.reshape((N,) + v_blk.shape[2:]), boundary.v[-1:]], axis=0),
    )
    return values_full, boundary, blocks, sub


def _recover_affine(grid: GridLQT, values_full: ValueFn, nsub: int,
                    mode: str,
                    prefix_scan_fn: Optional[Callable] = None) -> jnp.ndarray:
    """Method 1 (eq. 47): parallel RTS trajectory recovery."""
    Phi, beta = affine_recovery_maps(grid, values_full, mode)
    T = grid.N // nsub
    maps = AffineElement(
        Phi.reshape((T, nsub) + Phi.shape[1:]),
        beta.reshape((T, nsub) + beta.shape[1:]))

    # Within-block cumulative compose (collecting intermediates), vmapped.
    def block(ms):
        first = jax.tree_util.tree_map(lambda a: a[0], ms)
        rest = jax.tree_util.tree_map(lambda a: a[1:], ms)

        def step(carry, e):
            nxt = affine_combine(carry, e)
            return nxt, nxt

        last, tail = jax.lax.scan(step, first, rest)
        cum = jax.tree_util.tree_map(
            lambda f, t: jnp.concatenate([f[None], t], axis=0), first, tail)
        return cum, last

    cum, totals = jax.vmap(block)(maps)           # (T, n, ...), (T, ...)

    # Global prefix scan over block totals (eqs. 45-46).
    if prefix_scan_fn is not None:
        prefix = prefix_scan_fn(totals)                       # (T, ...)
    else:
        prefix = pscan.prefix_scan(affine_combine, totals)    # (T, ...)

    phi0 = jnp.linalg.solve(values_full.S[0], values_full.v[0])
    bound = (jnp.einsum("tij,j->ti", prefix.Phi, phi0) + prefix.beta)
    starts = jnp.concatenate([phi0[None], bound[:-1]], axis=0)  # (T, nx)

    # phi at tau_{i*n + l + 1} = cum[i, l] applied to starts[i].
    sub = (jnp.einsum("tlij,tj->tli", cum.Phi, starts) + cum.beta)
    phi = jnp.concatenate(
        [phi0[None], sub.reshape((grid.N,) + sub.shape[2:])], axis=0)
    return phi


def parallel_rts(
    grid: GridLQT, nsub: int, mode: str = "euler",
    combine_fn: Callable = lqt_combine,
    suffix_scan_fn: Optional[Callable] = None,
    prefix_scan_fn: Optional[Callable] = None,
) -> MAPSolution:
    """Parallel continuous-time RTS smoother (sections 4.1-4.3, method 1).

    ``suffix_scan_fn`` (elems -> inclusive suffix combine) replaces the
    default on-chip associative scan of the backward pass; the
    ``parallel_kernel`` method passes the lane-major Pallas scan
    (:func:`repro.kernels.lqt_combine.ops.kernel_suffix_scan`) here, the
    ``distributed`` method passes the time-axis-sharded scan
    (:func:`repro.core.pscan.sharded_scan`).  ``prefix_scan_fn`` does the
    same for the affine recovery scan of the forward pass (eqs. 45-46).
    """
    values_full, _, _, _ = parallel_backward(
        grid, nsub, mode, combine_fn=combine_fn,
        suffix_scan_fn=suffix_scan_fn)
    phi = _recover_affine(grid, values_full, nsub, mode,
                          prefix_scan_fn=prefix_scan_fn)
    return MAPSolution(
        x=jnp.flip(phi, axis=0),
        S=jnp.flip(values_full.S, axis=0),
        v=jnp.flip(values_full.v, axis=0))


def parallel_two_filter(
    grid: GridLQT, nsub: int, mode: str = "euler",
    combine_fn: Callable = lqt_combine,
    jitter: float = 1e-9,
    block0_fill: str = "affine",
    tf_fill: str = "combine",
) -> MAPSolution:
    """Parallel continuous-time two-filter smoother (section 4.3, method 2).

    ``block0_fill`` selects the interior recovery inside the first block,
    where the forward value function has not yet accumulated invertible
    information: ``"affine"`` (default) propagates the exact optimal
    trajectory maps from phi*(tau_0) (robust, no jitter); ``"min_initial"``
    follows eq. (39) with jitter-regularised eq. (50) pointwise (pure
    two-filter form).  Covariances inside block 0 are only available with
    ``"min_initial"`` (NaN otherwise); boundary and later-block covariances
    are always exact.

    ``tf_fill`` selects the interior fill for blocks >= 1 in ``euler``
    mode: ``"combine"`` (default) composes closed-form one-substep elements
    exactly -- unconditionally stable; ``"hjb_euler"`` is the paper-literal
    explicit Euler on the forward HJB ODEs (51), which is stiff in the
    covariance form when C H^T R^{-1} H dt approaches 1 (weakly observed
    state directions grow C without bound); see DESIGN.md S6 stability
    note.  ``discrete`` mode always uses exact combines.
    """
    values_full, boundary, blocks, sub = parallel_backward(
        grid, nsub, mode, combine_fn=combine_fn)
    T = grid.N // nsub
    nx = grid.nx

    # Forward prefix scan of [e (x) a_0, a_1, ..., a_{T-1}]  (eqs. 49-50).
    a0 = jax.tree_util.tree_map(lambda a: a[0], blocks)
    a0bar = elem_min_initial(a0, jitter=jitter)
    rest = jax.tree_util.tree_map(lambda a: a[1:], blocks)
    fwd_elems = _prepend_elem(a0bar, rest)
    fwd = pscan.prefix_scan(combine_fn, fwd_elems)            # (T, ...)

    # Block-boundary states via eq. (48).
    phi_b, cov_b = two_filter_combine(fwd, boundary.S[1:], boundary.v[1:])
    phi0 = jnp.linalg.solve(boundary.S[0], boundary.v[0])
    cov0 = jnp.linalg.inv(boundary.S[0])

    # Interior fill for blocks 1..T-1: forward HJB (51) from fwd[i-1].
    left = jax.tree_util.tree_map(lambda a: a[:-1], fwd)      # (T-1, ...)
    grid_tail = GridLQT(
        dt=grid.dt[nsub:], F=grid.F[nsub:], c=grid.c[nsub:],
        H=grid.H[nsub:], r=grid.r[nsub:], Q=grid.Q[nsub:],
        Rinv=grid.Rinv[nsub:], y=grid.y[nsub:],
        S_T=grid.S_T, v_T=grid.v_T,
        lin=None if grid.lin is None else grid.lin[nsub:])
    if mode == "discrete":
        sub_tail = jax.tree_util.tree_map(lambda a: a[1:], sub)
        fill = forward_value_fill_discrete(sub_tail, left)
    elif tf_fill == "combine":
        ones = one_step_elements(grid)
        T_blocks = grid.N // nsub
        sub_all = jax.tree_util.tree_map(
            lambda a: a.reshape((T_blocks, nsub) + a.shape[1:]), ones)
        sub_tail = jax.tree_util.tree_map(lambda a: a[1:], sub_all)
        fill = forward_value_fill_discrete(sub_tail, left)
    elif tf_fill == "hjb_euler":
        fill = forward_value_fill_euler(grid_tail, nsub, left)
    else:
        raise ValueError(f"unknown tf_fill: {tf_fill}")
    # fill: (T-1, n, ...) at right points tau_{i*n + l + 1}, blocks i>=1.
    S_right = values_full.S[nsub + 1:]
    v_right = values_full.v[nsub + 1:]
    flat_fill = jax.tree_util.tree_map(
        lambda a: a.reshape((grid.N - nsub,) + a.shape[2:]), fill)
    phi_tail, cov_tail = two_filter_combine(flat_fill, S_right, v_right)
    # parallel-consistent block boundaries: overwrite l = n-1 entries
    phi_tail = phi_tail.reshape(T - 1, nsub, nx).at[:, -1].set(phi_b[1:])
    cov_tail = cov_tail.reshape(T - 1, nsub, nx, nx).at[:, -1].set(cov_b[1:])
    phi_tail = phi_tail.reshape(grid.N - nsub, nx)
    cov_tail = cov_tail.reshape(grid.N - nsub, nx, nx)

    # Block-0 interior (tau_1 .. tau_{n-1}) + its right boundary tau_n.
    if block0_fill == "affine":
        Phi, beta = affine_recovery_maps(
            GridLQT(dt=grid.dt[:nsub], F=grid.F[:nsub], c=grid.c[:nsub],
                    H=grid.H[:nsub], r=grid.r[:nsub], Q=grid.Q[:nsub],
                    Rinv=grid.Rinv[:nsub], y=grid.y[:nsub],
                    S_T=grid.S_T, v_T=grid.v_T,
                    lin=None if grid.lin is None else grid.lin[:nsub]),
            ValueFn(values_full.S[:nsub + 1], values_full.v[:nsub + 1]),
            mode)

        def step(carry, inp):
            P, b = inp
            nxt = P @ carry + b
            return nxt, nxt

        _, phi_blk0 = jax.lax.scan(step, phi0, (Phi, beta))   # (n, nx)
        cov_blk0 = jnp.full((nsub, nx, nx), jnp.nan, dtype=cov_b.dtype)
    elif block0_fill == "min_initial":
        e_id = identity_element(nx, grid.F.dtype)
        if mode == "discrete":
            sub0 = jax.tree_util.tree_map(lambda a: a[0][None], sub)
        else:
            sub0 = None
        left0 = jax.tree_util.tree_map(lambda a: a[None], e_id)
        grid_head = GridLQT(
            dt=grid.dt[:nsub], F=grid.F[:nsub], c=grid.c[:nsub],
            H=grid.H[:nsub], r=grid.r[:nsub], Q=grid.Q[:nsub],
            Rinv=grid.Rinv[:nsub], y=grid.y[:nsub],
            S_T=grid.S_T, v_T=grid.v_T,
            lin=None if grid.lin is None else grid.lin[:nsub])
        if mode == "discrete":
            f0 = forward_value_fill_discrete(sub0, left0)
        else:
            f0 = forward_value_fill_euler(grid_head, nsub, left0)
        f0 = jax.tree_util.tree_map(lambda a: a[0], f0)       # (n, ...)
        folded = jax.vmap(lambda e: elem_min_initial(e, jitter=jitter))(f0)
        phi_blk0, cov_blk0 = two_filter_combine(
            folded, values_full.S[1:nsub + 1], values_full.v[1:nsub + 1])
    else:
        raise ValueError(f"unknown block0_fill: {block0_fill}")
    phi_blk0 = phi_blk0.at[-1].set(phi_b[0])
    cov_blk0 = cov_blk0.at[-1].set(cov_b[0])

    phi = jnp.concatenate([phi0[None], phi_blk0, phi_tail], axis=0)
    cov = jnp.concatenate([cov0[None], cov_blk0, cov_tail], axis=0)
    return MAPSolution(
        x=jnp.flip(phi, axis=0),
        S=jnp.flip(values_full.S, axis=0),
        v=jnp.flip(values_full.v, axis=0),
        cov=jnp.flip(cov, axis=0))
