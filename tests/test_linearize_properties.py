"""Hypothesis property tests for the sigma-point generators and SLR.

Randomised counterparts of the deterministic checks in
``tests/test_linearize.py``: weight normalisation and moment matching
over the whole valid parameter space of each family, and exact affine
recovery of SLR (the SLR == Taylor-on-linear-models property) under
random affine maps, spreads and nominal points.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.linearize import (
    SLR,
    Cubature,
    GaussHermite,
    Unscented,
    unit_points,
)


def families(max_n):
    """Strategy over (family, n) pairs valid for state dimension n."""
    ns = st.integers(min_value=1, max_value=max_n)
    unscented = st.builds(
        Unscented,
        alpha=st.floats(min_value=0.2, max_value=2.0),
        beta=st.floats(min_value=0.0, max_value=3.0),
        kappa=st.one_of(st.none(), st.floats(min_value=0.0, max_value=4.0)))
    cubature = st.just(Cubature())
    gh = st.builds(GaussHermite, order=st.integers(min_value=2, max_value=4))
    return st.tuples(st.one_of(unscented, cubature, gh), ns)


@settings(max_examples=60, deadline=None)
@given(families(max_n=4))
def test_weights_sum_to_one(fam_n):
    family, n = fam_n
    pts = unit_points(family, n)
    assert pts.points.shape == (family.num_points(n), n)
    np.testing.assert_allclose(np.sum(pts.wm), 1.0, rtol=0, atol=1e-12)


@settings(max_examples=60, deadline=None)
@given(families(max_n=4))
def test_points_reproduce_standard_moments(fam_n):
    family, n = fam_n
    pts = unit_points(family, n)
    np.testing.assert_allclose(pts.wm @ pts.points, np.zeros(n),
                               rtol=0, atol=1e-11)
    cov = np.einsum("s,si,sj->ij", pts.wc, pts.points, pts.points)
    np.testing.assert_allclose(cov, np.eye(n), rtol=0, atol=1e-10)


@settings(max_examples=40, deadline=None)
@given(families(max_n=3),
       st.integers(min_value=1, max_value=3),
       st.floats(min_value=1e-3, max_value=10.0),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_slr_recovers_affine(fam_n, nz, spread, seed):
    """SLR of an affine g returns its (A, b) exactly and Omega == 0,
    for every family, output dimension, spread scale and random draw."""
    family, n = fam_n
    rng = np.random.default_rng(seed)
    A_true = jnp.asarray(rng.standard_normal((nz, n)))
    b_true = jnp.asarray(rng.standard_normal(nz))
    m = jnp.asarray(rng.standard_normal(n))
    W = rng.standard_normal((n, n))
    cov = jnp.asarray(W @ W.T / n + np.eye(n))

    def g(x, t):
        return A_true @ x + b_true

    A, b, Omega = SLR(family, spread=spread)(g, m, 0.0, cov)
    scale = max(1.0, float(np.max(np.abs(A_true))))
    np.testing.assert_allclose(A, A_true, rtol=0, atol=1e-9 * scale)
    np.testing.assert_allclose(b, b_true, rtol=0,
                               atol=1e-8 * max(1.0, float(np.max(np.abs(m)))
                                               * scale))
    np.testing.assert_allclose(Omega, np.zeros((nz, nz)), rtol=0,
                               atol=1e-8 * scale ** 2)
