"""Hypothesis property tests for the Pallas ``lqt_combine`` kernel
(interpret mode): combine associativity on the batched lane layout, the
zero-lane padding contract of the block wrapper, and identity elements
being two-sided identities of the combine (the padding elements of
bucketed kernel scans)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.core.elements import identity_element
from repro.core.types import LQTElement
from repro.kernels.lqt_combine import lqt_combine_batched, lqt_combine_ref
from repro.kernels.lqt_combine.kernel import lqt_combine_lanes
from repro.kernels.lqt_combine.ops import _pad_lanes, _to_lanes

pytestmark = pytest.mark.kernel_interpret


def _rand_batch(rng, B, n) -> LQTElement:
    def psd():
        A = rng.standard_normal((B, n, n))
        return jnp.asarray(np.einsum("bij,bkj->bik", A, A) / n
                           + 0.1 * np.eye(n))

    return LQTElement(
        jnp.asarray(rng.standard_normal((B, n, n)) * 0.6),
        jnp.asarray(rng.standard_normal((B, n))),
        psd(),
        jnp.asarray(rng.standard_normal((B, n))),
        psd())


def _combine(e1, e2):
    return lqt_combine_batched(e1, e2, interpret=True, block_b=8)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(1, 9))
def test_kernel_combine_associative(seed, n, B):
    """(e1 (x) e2) (x) e3 == e1 (x) (e2 (x) e3) through the kernel."""
    rng = np.random.default_rng(seed)
    e1, e2, e3 = (_rand_batch(rng, B, n) for _ in range(3))
    left = _combine(_combine(e1, e2), e3)
    right = _combine(e1, _combine(e2, e3))
    for a, b in zip(left, right):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-7, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(1, 12))
def test_zero_padded_lanes_are_garbage_free(seed, n, B):
    """Zero lanes appended by ``_pad_lanes`` combine to exact zeros (the
    Gauss-Jordan sees M = I) and never perturb the real lanes."""
    rng = np.random.default_rng(seed)
    e1, e2 = _rand_batch(rng, B, n), _rand_batch(rng, B, n)
    pad = (-(B + 3)) % 8 + 3                     # a nonzero pad amount
    ops1 = _pad_lanes(_to_lanes(e1), pad)
    ops2 = _pad_lanes(_to_lanes(e2), pad)
    bb = ops1[0].shape[-1]
    out = lqt_combine_lanes(ops1, ops2, block_b=bb, interpret=True)
    want = lqt_combine_ref(*e1, *e2)
    for got_lane, w in zip(out, want):
        # real lanes: exact combine of the unpadded operands
        got = np.moveaxis(np.asarray(got_lane), -1, 0)[:B]
        np.testing.assert_allclose(got, np.asarray(w), rtol=1e-9, atol=1e-9)
        # pad lanes: identically zero
        assert not np.any(np.asarray(got_lane)[..., B:])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(1, 9))
def test_identity_element_is_two_sided_identity(seed, n, B):
    """combine(e, id) == combine(id, e) == e: identity elements are safe
    scan padding on either side (eq. 34's zero-length interval)."""
    rng = np.random.default_rng(seed)
    e = _rand_batch(rng, B, n)
    eid = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (B,) + a.shape),
        identity_element(n, e.A.dtype))
    for got in (_combine(e, eid), _combine(eid, e)):
        for a, b in zip(got, e):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-9, atol=1e-9)
