"""Training loop: jitted train_step factory, microbatching, checkpoints,
preemption handling.

``make_train_step`` builds the pjit-able step for any zoo architecture:
loss -> grad (with per-layer remat via the model stack) -> grad-accumulation
over microbatches (``lax.scan``) -> AdamW.  Under an active mesh the step is
jitted with NamedShardings derived from the logical axes (params: TP over
'model'; optimizer state: + ZeRO-1 over 'data'; batch over ('pod','data')).

Fault tolerance: ``Trainer.run`` checkpoints every ``checkpoint_every``
steps and on SIGTERM, auto-resumes from the newest valid checkpoint, and
keeps the data pipeline stateless (step-indexed) so restarts replay
identically regardless of mesh shape (straggler/elastic recovery story in
DESIGN.md S5).
"""
from __future__ import annotations

import dataclasses
import functools
import signal
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.distributed import sharding as shd
from repro.models import transformer

from . import checkpoint as ckpt
from .optimizer import (
    AdamWState, adamw_init, adamw_update, cosine_schedule, opt_state_axes,
)


def _zero2_constrain(grads, cfg: ModelConfig):
    """ZeRO-2-style grad sharding: constrain the accumulation buffer to
    the optimizer-state (zero1) layout so XLA reduce-scatters each
    microbatch's gradients instead of holding a replicated f32 copy
    (136 GB of llava grads / 16 TP shards would otherwise cost
    8.5 GB/device).  No-op without an active mesh."""
    from repro.distributed import sharding as shd
    from .optimizer import zero1_logical

    if shd.active_mesh() is None:
        return grads
    data_size = shd.data_parallel_size()
    axes = transformer.axes(cfg)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def leaf(ax, g):
        zax = zero1_logical(ax, g.shape, data_size)
        return shd.logical_constraint(g, *zax)

    return jax.tree_util.tree_map(leaf, axes, grads, is_leaf=is_ax)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    loss_fn: Optional[Callable] = None):
    """Returns ``step(params, opt, batch) -> (params, opt, metrics)``."""
    schedule = cosine_schedule(tcfg)
    loss_fn = loss_fn or functools.partial(transformer.train_loss, cfg=cfg)
    compute_dtype = {"bfloat16": jnp.bfloat16,
                     "float32": jnp.float32}[cfg.dtype]

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(p, batch))(params)

    def step(params, opt: AdamWState, batch):
        if tcfg.microbatches > 1:
            def split(x):
                return x.reshape((tcfg.microbatches,
                                  x.shape[0] // tcfg.microbatches)
                                 + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def accum(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                g = _zero2_constrain(g, cfg)
                return (loss_acc + loss,
                        jax.tree_util.tree_map(jnp.add, g_acc, g)), None

            zeros = _zero2_constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params), cfg)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), micro)
            inv = 1.0 / tcfg.microbatches
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        else:
            loss, grads = grads_of(params, batch)
            grads = _zero2_constrain(grads, cfg)
        params, opt, stats = adamw_update(
            grads, opt, tcfg, schedule, compute_dtype)
        return params, opt, {"loss": loss, **stats}

    return step


def make_shardings(cfg: ModelConfig, tcfg: TrainConfig, mesh):
    """NamedShardings for (params, opt_state, batch) under ``mesh``."""
    axes = transformer.axes(cfg)
    shapes = transformer.shapes(cfg)
    p_shard = shd.tree_shardings(axes, shapes, mesh)
    data_size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            data_size *= mesh.shape[a]
    o_axes = opt_state_axes(axes, shapes, data_size, zero1=tcfg.zero1)
    from jax.sharding import NamedSharding, PartitionSpec as P
    o_m = shd.tree_shardings(o_axes.m, shapes, mesh)
    o_v = shd.tree_shardings(o_axes.v, shapes, mesh)
    o_master = shd.tree_shardings(o_axes.master, shapes, mesh)
    o_shard = AdamWState(
        step=NamedSharding(mesh, P()), m=o_m, v=o_v, master=o_master)
    return p_shard, o_shard


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainConfig
    pipeline: Any
    ckpt_dir: str
    loss_fn: Optional[Callable] = None
    log_fn: Callable = print

    def __post_init__(self):
        self._stop_requested = False

    def _install_sigterm(self):
        def handler(signum, frame):
            self._stop_requested = True
            self.log_fn("[trainer] SIGTERM: will checkpoint and exit")

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not in main thread (tests)

    def run(self, steps: Optional[int] = None):
        self._install_sigterm()
        cfg, tcfg = self.cfg, self.tcfg
        key = jax.random.PRNGKey(tcfg.seed)
        params = transformer.init(cfg, key)
        opt = adamw_init(params)
        start_step = 0

        latest = ckpt.latest_checkpoint(self.ckpt_dir)
        if latest:
            start_step, (params, opt) = ckpt.restore_checkpoint(
                latest, (params, opt))
            self.log_fn(f"[trainer] resumed from {latest} @ {start_step}")

        step_fn = jax.jit(make_train_step(cfg, tcfg, self.loss_fn))
        total = steps if steps is not None else tcfg.total_steps
        metrics = {}
        t0 = time.time()
        for step in range(start_step, total):
            batch = self.pipeline.batch_at(step)
            params, opt, metrics = step_fn(params, opt, batch)
            if (step + 1) % tcfg.log_every == 0:
                loss = float(metrics["loss"])
                dt = (time.time() - t0) / tcfg.log_every
                self.log_fn(
                    f"[trainer] step {step + 1} loss={loss:.4f} "
                    f"lr={float(metrics['lr']):.2e} {dt:.2f}s/step")
                t0 = time.time()
            want_ckpt = ((step + 1) % tcfg.checkpoint_every == 0
                         or self._stop_requested or step + 1 == total)
            if want_ckpt:
                path = ckpt.save_checkpoint(
                    self.ckpt_dir, step + 1, (params, opt))
                ckpt.prune_checkpoints(self.ckpt_dir,
                                       tcfg.keep_checkpoints)
                self.log_fn(f"[trainer] saved {path}")
            if self._stop_requested:
                break
        return params, opt, metrics
