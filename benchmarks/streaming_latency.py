"""Streaming-latency benchmark: ``StreamingEngine`` window latency
percentiles and track throughput.

The serving question for the STREAMING engine is not drain throughput of
whole records but the freshness of a fixed-lag estimate: when a client
pushes measurements, how long until the window containing them is
re-solved?  This drives a deterministic multi-track workload (fixed seed;
every track pushes ``chunk``-interval pieces round-robin, the engine
drains between rounds so windows from different tracks batch into shared
waves) twice -- a warmup pass that compiles the per-bucket executables,
then a measured pass on fresh tracks running entirely on cache hits --
and reports tracks/sec and windows/sec (measured pass) plus the p50/p99
of the ``stream.window_latency_seconds`` obs histogram (push-to-solved
wall time per window; the histogram covers both passes, so p99 exposes
compile-inflated first-wave latency while p50 reflects steady state).

    PYTHONPATH=src python benchmarks/streaming_latency.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np


def _stream_pass(engine, ts, tracks_y, chunk):
    """Round-robin the tracks' chunks through the engine; returns
    (tracks, windows_solved)."""
    tids = [engine.open_track(ts[0]) for _ in tracks_y]
    N = tracks_y[0].shape[0]
    windows = 0
    for i in range(0, N, chunk):
        for tid, y in zip(tids, tracks_y):
            k = min(chunk, N - i)
            engine.push(tid, ts[i + 1:i + 1 + k], y[i:i + k])
        windows += engine.run()
    for tid in tids:
        engine.close(tid)
    return len(tids), windows


def run(smoke=False, seed=0):
    import repro.obs as obs
    from repro.configs.wiener_velocity import WienerVelocityConfig
    from repro.serving import StreamingEngine

    model = WienerVelocityConfig(p0=1.0).model()
    if smoke:
        batch, n_tracks, N, chunk, lag = 4, 4, 40, 10, 16
    else:
        batch, n_tracks, N, chunk, lag = 8, 16, 200, 20, 64
    rng = np.random.default_rng(seed)
    ny = np.asarray(model.H).shape[0]
    ts = np.linspace(0.0, N / 32.0, N + 1, dtype=np.float32)
    tracks_y = [rng.standard_normal((N, ny)).astype(np.float32)
                for _ in range(n_tracks)]

    engine = StreamingEngine(model, lag=lag, batch=batch)
    _stream_pass(engine, ts, tracks_y, chunk)   # warmup: compiles buckets

    t0 = time.perf_counter()
    tracks, windows = _stream_pass(engine, ts, tracks_y, chunk)
    dt = time.perf_counter() - t0

    derived = (f"tracks_per_sec={tracks / dt:.1f}"
               f",windows_per_sec={windows / dt:.1f}")
    if obs.enabled():
        lat = obs.histogram("stream.window_latency_seconds").summary()
        if lat.get("count"):
            derived += (f",p50_ms={lat['p50'] * 1e3:.2f}"
                        f",p99_ms={lat['p99'] * 1e3:.2f}")
        waste = obs.gauge("stream.padding_waste").value
        derived += f",waste={waste:.3f}"
    return [{
        "name": f"stream/fixedlag/B{batch}_T{n_tracks}_L{lag}",
        "us_per_call": dt / windows * 1e6,
        "derived": derived,
    }]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI bit-rot check)")
    args = ap.parse_args()
    import repro.obs as obs
    obs.enable()
    for r in run(smoke=args.smoke):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
