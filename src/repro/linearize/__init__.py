"""``repro.linearize`` -- pluggable linearisation strategies.

The iterated nonlinear smoother needs an affine surrogate of the drift
``f`` and measurement ``h`` at every grid point; this package makes that
step a strategy (docs/LINEARIZATION.md):

    from repro.linearize import get_linearization
    lin = get_linearization("unscented")      # or "taylor", an instance, ...
    A, b, Omega = lin(g, xbar, t, cov)

Built-ins: ``taylor`` (Jacobian, the IEKS default -- bit-exact with the
pre-subsystem code path), and sigma-point statistical linear regression
via ``unscented`` / ``cubature`` / ``gauss_hermite`` (derivative-free,
residual covariance folded into the noise -- the posterior-linearisation
smoother of arXiv 2102.00514).  Select with
``IteratedOptions(linearization=...)`` or ``method="sigma_point"``.
"""
from .base import (
    Linearization,
    get_linearization,
    linearization_names,
    register_linearization,
)
from .sigma_points import (
    Cubature,
    GaussHermite,
    SigmaPointFamily,
    SigmaPoints,
    Unscented,
    unit_points,
)
from .slr import SLR, cubature, gauss_hermite, slr_linearize_point, unscented
from .taylor import Taylor, taylor_linearize_grid, taylor_linearize_point

__all__ = [
    "Linearization",
    "get_linearization",
    "linearization_names",
    "register_linearization",
    "Taylor",
    "taylor_linearize_point",
    "taylor_linearize_grid",
    "SLR",
    "slr_linearize_point",
    "unscented",
    "cubature",
    "gauss_hermite",
    "SigmaPointFamily",
    "SigmaPoints",
    "Unscented",
    "Cubature",
    "GaussHermite",
    "unit_points",
]
