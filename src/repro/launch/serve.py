"""Serving driver: batched greedy generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m-smoke \
        --requests 6 --prompt-len 12 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config
from repro.models import transformer
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit("encoder-only architectures have no decode step")
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch=args.batch,
                         max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"[serve] arch={cfg.name} {len(done)} requests, "
          f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt={r.prompt[:6]}... out={r.out}")


if __name__ == "__main__":
    main()
