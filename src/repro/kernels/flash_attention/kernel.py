"""Pallas TPU kernel: causal / sliding-window GQA flash attention.

Standard streaming-softmax decomposition with TPU tiling:

* grid = (B*Hq, Lq/BQ, Lk/BK); the KV dimension is ARBITRARY (sequential)
  and carries the running max / normaliser / accumulator in VMEM scratch.
* BlockSpec index maps implement GQA by folding the q-head -> kv-head
  mapping into the K/V block indices (no repeated K/V materialisation).
* fully-masked KV blocks (beyond the causal frontier or outside the
  sliding window) are skipped with ``pl.when`` -- the O(L^2) -> O(L*W)
  saving for SWA happens here.
* MXU alignment: BQ/BK default to 128 and D is the model head_dim (a
  multiple of 8 for all configs in this repo); logits/accumulator are f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 bq: int, bk: int, lk_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global row/col positions of this tile (q offset by lk_offset for
    # decode-style Lq < Lk usage)
    q_start = qi * bq + lk_offset
    k_start = kj * bk

    def needed():
        ok = True
        if causal:
            ok = jnp.logical_and(ok, k_start <= q_start + bq - 1)
        if window is not None:
            ok = jnp.logical_and(ok, k_start + bk - 1 > q_start - window)
        return ok

    @pl.when(needed())
    def _compute():
        q = q_ref[0, 0]                    # (BQ, D)
        k = k_ref[0, 0]                    # (BK, D)
        v = v_ref[0, 0]                    # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Flash attention with GQA head folding.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D).  Lq may be < Lk (the q rows
    are aligned to the END of the key sequence, e.g. decode steps).
    """
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    bq = min(block_q, Lq)
    bk = min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, (Lq, bq, Lk, bk)
    grid = (B * Hq, Lq // bq, Lk // bk)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, lk_offset=Lk - Lq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, qi, kj: (bh // Hq, bh % Hq, qi, 0)),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda bh, qi, kj: (bh // Hq, (bh % Hq) // rep, kj, 0)),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda bh, qi, kj: (bh // Hq, (bh % Hq) // rep, kj, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, D), lambda bh, qi, kj: (bh // Hq, bh % Hq, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
