"""Step functions + ShapeDtypeStruct input specs for every dry-run cell.

``input_specs(cfg, shape)`` returns exactly the abstract arrays the cell's
step function consumes (weak-type-correct, shardable, no allocation); the
dry-run lowers ``jax.jit(step).lower(**specs)`` and compiles.

Cell kinds:
  train   -> ``train_step``  (loss + grads + AdamW update)
  prefill -> ``prefill_step`` (full forward, last-token logits + caches)
  decode  -> ``serve_step``  (one token against a seq_len-deep cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer
from repro.train.optimizer import adamw_init
from repro.train.trainer import make_train_step


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {}
        if cfg.input_mode == "embeddings":
            specs["embeddings"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), _dtype(cfg))
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
    # decode: one token + caches of depth seq_len
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    caches = jax.eval_shape(
        lambda: transformer.init_caches(cfg, shape.global_batch,
                                        shape.seq_len))
    return caches


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))


def opt_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: adamw_init(transformer.init(cfg, jax.random.PRNGKey(0))))


def make_step(cfg: ModelConfig, shape: ShapeConfig, tcfg: TrainConfig,
              **model_kw):
    """Returns (step_fn, example_kwargs_specs) for the cell."""
    if shape.kind == "train":
        loss_fn = functools.partial(
            transformer.train_loss, cfg=cfg, **model_kw)
        inner = make_train_step(cfg, tcfg, loss_fn)

        def train_step(params, opt, batch):
            return inner(params, opt, batch)

        specs = {
            "params": params_specs(cfg),
            "opt": opt_specs(cfg),
            "batch": batch_specs(cfg, shape),
        }
        return train_step, specs

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return transformer.prefill(
                params, batch, cfg, max_len=shape.seq_len, **model_kw)

        return prefill_step, {
            "params": params_specs(cfg),
            "batch": batch_specs(cfg, shape),
        }

    def serve_step(params, tokens, caches):
        return transformer.decode_step(params, tokens, caches, cfg)

    return serve_step, {
        "params": params_specs(cfg),
        "tokens": batch_specs(cfg, shape)["tokens"],
        "caches": cache_specs(cfg, shape),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                tcfg: TrainConfig = None) -> Dict[str, Any]:
    _, specs = make_step(cfg, shape, tcfg or TrainConfig())
    return specs


def cache_pspecs(cfg: ModelConfig, mesh, global_batch: int = 0):
    """PartitionSpecs for the stacked decode caches.

    Leading axis is LAYERS (the decode scan) -- never sharded; batch over
    (pod, data) with progressive fallback when the batch does not divide
    (long_500k has batch 1); kv/ssm heads over model with head_dim
    fallback (the same divisibility rule as the weights).
    """
    from jax.sharding import PartitionSpec as P

    from repro.models.attention import KVCache
    from repro.models.ssm import SSMCache
    from repro.models.transformer import LayerCaches

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while data_axes and global_batch:
        size = 1
        for a in data_axes:
            size *= mesh.shape[a]
        if global_batch % size == 0:
            break
        data_axes = data_axes[1:]
    d = (data_axes if len(data_axes) > 1 else
         (data_axes[0] if data_axes else None))
    m = mesh.shape["model"] if "model" in mesh.axis_names else 1

    attn = ssm = None
    if cfg.mixer in ("attn", "hybrid"):
        if cfg.num_kv_heads % m == 0:
            kv = P(None, d, "model", None, None)
        elif cfg.hd % m == 0 and not cfg.kv_replicate:
            kv = P(None, d, None, None, "model")
        else:
            kv = P(None, d, None, None, None)
        attn = KVCache(k=kv, v=kv, pos=P(None))
    if cfg.mixer in ("ssm", "hybrid"):
        conv_dim = cfg.ssm_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        conv = P(None, d, None, "model" if conv_dim % m == 0 else None)
        if cfg.ssm_heads % m == 0:
            state = P(None, d, "model", None, None)
        elif cfg.ssm_head_dim % m == 0:
            state = P(None, d, None, "model", None)
        else:
            state = P(None, d, None, None, None)
        ssm = SSMCache(conv=conv, state=state)
    return LayerCaches(attn, ssm)
