from . import sharding
from .sharding import (
    choose_pspec, logical_constraint, mesh_context, named_sharding,
    tree_pspecs, tree_shardings,
)
