"""Scan machinery tests, incl. the multi-device distributed scan.

The distributed test spawns a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count is
locked at first jax init, so it cannot run in-process).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affine_combine, prefix_scan, suffix_scan
from repro.core.types import AffineElement


def test_prefix_equals_suffix_on_reversed():
    rng = np.random.default_rng(3)
    T, n = 13, 3
    e = AffineElement(jnp.asarray(rng.standard_normal((T, n, n))),
                      jnp.asarray(rng.standard_normal((T, n))))
    suf = suffix_scan(affine_combine, e)
    # suffix of e == flip(prefix of flipped-with-swapped-op)
    flip = lambda x: jnp.flip(x, 0)
    pre = prefix_scan(lambda a, b: affine_combine(b, a),
                      AffineElement(flip(e.Phi), flip(e.beta)))
    np.testing.assert_allclose(suf.Phi, flip(pre.Phi), rtol=1e-9, atol=1e-9)


def test_scan_under_jit_and_grad():
    rng = np.random.default_rng(4)
    T, n = 8, 2
    Phi = jnp.asarray(rng.standard_normal((T, n, n)))
    beta = jnp.asarray(rng.standard_normal((T, n)))

    @jax.jit
    def loss(Phi, beta):
        out = prefix_scan(affine_combine, AffineElement(Phi, beta))
        return jnp.sum(out.beta ** 2)

    g = jax.grad(loss)(Phi, beta)
    assert g.shape == Phi.shape
    assert bool(jnp.isfinite(g).all())


_DISTRIBUTED_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core import (affine_combine, lqt_combine, prefix_scan,
                            suffix_scan, distributed_scan)
    from repro.core.types import AffineElement, LQTElement

    mesh = jax.make_mesh((8,), ("t",))
    rng = np.random.default_rng(0)
    T, n = 64, 3

    # --- affine elements, prefix + suffix ---
    elems = AffineElement(jnp.asarray(rng.standard_normal((T, n, n))),
                          jnp.asarray(rng.standard_normal((T, n))))
    spec = AffineElement(P("t"), P("t"))
    for reverse in (False, True):
        f = shard_map(
            partial(distributed_scan, affine_combine, axis_name="t",
                    reverse=reverse),
            mesh=mesh, in_specs=(spec,), out_specs=spec)
        got = f(elems)
        want = (suffix_scan if reverse else prefix_scan)(
            affine_combine, elems)
        np.testing.assert_allclose(got.Phi, want.Phi, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(got.beta, want.beta, rtol=1e-9,
                                   atol=1e-9)

    # --- LQT elements (the paper's operator) ---
    def rand_psd(k):
        A = rng.standard_normal((k, n, n))
        return jnp.asarray(np.einsum("kij,klj->kil", A, A) / n
                           + 0.1 * np.eye(n))

    le = LQTElement(
        A=jnp.asarray(rng.standard_normal((T, n, n)) * 0.6),
        b=jnp.asarray(rng.standard_normal((T, n))),
        C=rand_psd(T), eta=jnp.asarray(rng.standard_normal((T, n))),
        J=rand_psd(T))
    lspec = LQTElement(*(P("t"),) * 5)
    f = shard_map(
        partial(distributed_scan, lqt_combine, axis_name="t", reverse=True),
        mesh=mesh, in_specs=(lspec,), out_specs=lspec)
    got = f(le)
    want = suffix_scan(lqt_combine, le)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-7, atol=1e-8)

    # --- sharded_scan: top-level entry, incl. non-divisible lengths ---
    from repro.core import sharded_scan
    for T2 in (64, 65, 67, 17, 8, 5):   # 5 < 2P: single-device degrade
        e2 = AffineElement(
            jnp.asarray(rng.standard_normal((T2, n, n)) * 0.5),
            jnp.asarray(rng.standard_normal((T2, n))))
        for reverse in (False, True):
            got = jax.jit(lambda e, r=reverse: sharded_scan(
                affine_combine, e, mesh=mesh, axis_name="t",
                reverse=r))(e2)
            want = (suffix_scan if reverse else prefix_scan)(
                affine_combine, e2)
            np.testing.assert_allclose(got.Phi, want.Phi,
                                       rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(got.beta, want.beta,
                                       rtol=1e-9, atol=1e-9)

    # carry_dtype: f32 elements with an f64 redundant carry scan stays
    # close to the full-f64 reference (and keeps the element dtype).
    e32 = AffineElement(
        jnp.asarray(rng.standard_normal((64, n, n)) * 0.5, jnp.float32),
        jnp.asarray(rng.standard_normal((64, n)), jnp.float32))
    got = jax.jit(lambda e: sharded_scan(
        affine_combine, e, mesh=mesh, axis_name="t",
        carry_dtype=jnp.float64))(e32)
    assert got.Phi.dtype == jnp.float32
    want = prefix_scan(affine_combine, e32)
    np.testing.assert_allclose(got.Phi, want.Phi, rtol=1e-4, atol=1e-4)
    print("DISTRIBUTED-SCAN-OK")
""")


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_scan_8_devices():
    """Real 8-device run: the subprocess pins the CPU platform, so the
    forced host-device count always materialises (no skip path)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _DISTRIBUTED_SNIPPET],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "DISTRIBUTED-SCAN-OK" in out.stdout
