from .ops import (
    kernel_prefix_scan,
    kernel_suffix_scan,
    lqt_combine_batched,
    scan_combine_fn,
)
from .ref import lqt_combine_ref, lqt_scan_ref
