"""Top-level user API for continuous-time MAP trajectory estimation.

    from repro.core import Estimator, Problem

    est = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=10, mode="discrete"))
    sol = est.solve(Problem.single(model, ts, y))

``model`` is a :class:`~repro.core.sde.LinearSDE` or
:class:`~repro.core.sde.NonlinearSDE`; nonlinear models are solved with
the iterated linearisation of section 4.4 (outer loop controlled by
:class:`~repro.core.options.IteratedOptions`).  Batches of measurement
records are :meth:`Problem.stacked` (records sharing a length) and
:meth:`Problem.ragged` (pad-and-bucket for ragged record lengths).

``measurement_mask`` zeroes the information contribution of selected
measurement intervals (mask 0.0) while keeping the dynamics prior intact;
it is what makes length-padding exact (a padded tail beyond ``t_f`` with
no measurements adds zero Onsager-Machlup cost and leaves the MAP estimate
on the real window unchanged), and it doubles as a missing-data mask.

This module keeps the LEGACY entry point :func:`map_estimate` as a thin
deprecation shim over the Estimator surface (see ``docs/MIGRATION.md``).
"""
from __future__ import annotations

import warnings
from typing import Optional, Union

import jax.numpy as jnp

from .estimator import Estimator, Problem, legacy_options
from .registry import method_names
from .sde import LinearSDE, NonlinearSDE


def map_estimate(
    model: Union[LinearSDE, NonlinearSDE],
    ts: jnp.ndarray,
    y: jnp.ndarray,
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
    measurement_mask: Optional[jnp.ndarray] = None,
):
    """Deprecated shim: use ``Estimator(model, method=..., options=...)
    .solve(Problem.single(model, ts, y))`` instead."""
    warnings.warn(
        "map_estimate is deprecated; use repro.core.Estimator with "
        "Problem.single (see docs/MIGRATION.md)",
        DeprecationWarning, stacklevel=2)
    est = Estimator(model, method=method,
                    options=legacy_options(
                        model, method, nsub=nsub, mode=mode,
                        iterations=iterations,
                        divergence_correction=divergence_correction))
    return est.solve(Problem.single(model, ts, y,
                                    measurement_mask=measurement_mask))


def __getattr__(name: str):
    # METHODS used to be a tuple snapshot frozen at import time, silently
    # missing methods added later via registry.register_method.  It is now
    # a live (deprecated) view; call method_names() instead.
    if name == "METHODS":
        warnings.warn(
            "METHODS is deprecated; call repro.core.method_names() for the "
            "live method list", DeprecationWarning, stacklevel=2)
        return method_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
