"""Pure-jnp oracle for the chunked SSD (state-space dual) scan.

The SSD recurrence IS the paper's affine trajectory recursion (eqs. 45-46)
with a DIAGONAL (here scalar-per-head) transition:

    h_t = exp(dt_t A_h) h_{t-1} + dt_t x_t (x) B_t        (Phi, beta)
    y_t = h_t C_t^T  (+ D_h x_t)

This reference computes it with a plain sequential ``lax.scan`` -- exact,
O(L) span -- and is the oracle for both the Pallas kernel and the chunked
jnp implementation used by the model stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D=None):
    """Sequential SSD scan.

    Args:
      x:  (batch, L, H, P)
      dt: (batch, L, H)      positive step sizes (already softplus'ed)
      A:  (H,)               negative per-head decay rates
      B:  (batch, L, G, S)   input projections (G groups, H % G == 0)
      C:  (batch, L, G, S)   output projections
      D:  optional (H,)      skip connection
    Returns:
      y: (batch, L, H, P)
    """
    b, L, H, P = x.shape
    G = B.shape[2]
    S = B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)   # (b, L, H, S)
    Ch = jnp.repeat(C, rep, axis=2)

    def per_seq(xs, dts, Bs, Cs):
        def step(h, inp):
            xk, dtk, Bk, Ck = inp          # (H,P), (H,), (H,S), (H,S)
            a = jnp.exp(dtk * A)           # (H,)
            h = a[:, None, None] * h + (dtk[:, None] * xk)[..., None] * Bk[:, None, :]
            y = jnp.einsum("hps,hs->hp", h, Ck)
            return h, y

        h0 = jnp.zeros((H, P, S), dtype=jnp.promote_types(xs.dtype, jnp.float32))
        _, ys = jax.lax.scan(step, h0, (xs, dts, Bs, Cs))
        return ys

    y = jax.vmap(per_seq)(x, dt, Bh, Ch)
    y = y.astype(x.dtype)
    if D is not None:
        y = y + D[None, None, :, None] * x
    return y
