"""Iterated linearisation for nonlinear models (section 4.4).

Continuous-time iterated extended Kalman smoother: linearise (1) about the
current nominal trajectory, solve the resulting linear-affine MAP problem
with the sequential or PARALLEL smoother, re-linearise, repeat.  Every
iteration is parallel-in-time when the inner method is a parallel solver,
which is exactly the paper's Fig.-2 experiment (5 iterations on the
coordinated-turn model).

The default drops the second-order Onsager-Machlup divergence correction
(as the paper's IEKS does -- for linear-affine subproblems div f~ is
constant); ``divergence_correction=True`` folds the linearised 1/2 div f
term in as an extra linear running cost (DESIGN.md S1).

:func:`iterated_solve` is the engine room used by
:class:`repro.core.Estimator`; the old :func:`iterated_map` entry point
remains as a deprecation shim around the Estimator surface.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .sde import (
    NonlinearSDE,
    Prior,
    grid_lqt_from_nonlinear,
    om_cost_nonlinear,
)
from .types import MAPSolution


def iterated_solve(
    model: NonlinearSDE,
    ts: jnp.ndarray,
    y: jnp.ndarray,
    solver: Callable,
    *,
    iterations: int = 5,
    divergence_correction: bool = False,
    x_init: jnp.ndarray | None = None,
    measurement_mask: Optional[jnp.ndarray] = None,
    prior: Optional[Prior] = None,
    track_costs: bool = True,
    linearization=None,
) -> Tuple[MAPSolution, Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    """Continuous-time iterated MAP estimation (paper section 5.2).

    ``solver`` maps a linearised :class:`~repro.core.types.GridLQT` to a
    :class:`MAPSolution` (method + options already bound).  ``iterations``
    fixed Gauss-Newton style passes (paper uses 5); the initial nominal
    trajectory defaults to the constant prior mean.  ``x_init`` may be a
    full nominal trajectory ``(N+1, nx)`` or a single state ``(nx,)`` that
    is broadcast along time -- the latter is the batch-friendly form (a
    per-record warm-start point vmaps over records of any padded length).
    ``measurement_mask`` (``(N,)`` of 0/1) zeroes masked measurement
    intervals in every linearisation pass (padding / missing data).
    ``prior`` ``(S0, v0)`` replaces the model's ``(m0, P0)`` initial
    boundary with an information-form prior in every linearised subproblem
    AND in the cost trace -- fixed-lag window re-solves pass the filter
    information at the window's left edge here (docs/STREAMING.md).

    Returns the 3-tuple ``(solution, cost_trace, step_norms)``:
    ``cost_trace[i]``
    is the true (nonlinear) Onsager-Machlup cost of the iterate produced
    by pass ``i+1`` -- the Gauss-Newton descent curve; ``cost_trace[-1]``
    is the cost of the returned solution.  ``step_norms[i]`` is the RMS
    update norm ``sqrt(mean((x_{i+1} - x_i)^2))`` of pass ``i+1`` -- the
    convergence indicator surfaced as ``Solution.step_norms`` (and into
    the ``repro.obs`` registry by the Estimator).  ``track_costs=False``
    skips both trace evaluations (returning ``(solution, None, None)``)
    -- one model f/h sweep plus Q/R inversions saved per iteration.

    ``linearization`` selects the per-iteration linearisation strategy
    (``None`` = Taylor, i.e. the IEKS; a registered name or
    :class:`repro.linearize.Linearization` instance -- sigma-point SLR
    turns this into the iterated posterior-linearisation smoother).  The
    cost trace is always the TRUE nonlinear Onsager-Machlup cost, so
    traces are comparable across strategies.
    """
    from repro.linearize import get_linearization

    linearization = get_linearization(linearization)
    N = y.shape[0]
    if x_init is None:
        mean = (model.m0 if prior is None
                else jnp.linalg.solve(prior[0], prior[1]))
        x_init = jnp.broadcast_to(mean, (N + 1,) + mean.shape)
    elif x_init.ndim == 1:
        x_init = jnp.broadcast_to(x_init, (N + 1,) + x_init.shape)

    def cost_of(x):
        return om_cost_nonlinear(
            model, ts, y, x, divergence_correction=divergence_correction,
            measurement_mask=measurement_mask, prior=prior)

    def step_norm(x_new, x_old):
        return jnp.sqrt(jnp.mean(jnp.square(x_new - x_old)))

    def body(xbar, _):
        grid = grid_lqt_from_nonlinear(
            model, ts, y, xbar, divergence_correction=divergence_correction,
            measurement_mask=measurement_mask, prior=prior,
            linearization=linearization)
        sol = solver(grid)
        aux = ((cost_of(sol.x), step_norm(sol.x, xbar))
               if track_costs else None)
        return sol.x, aux

    # iterations-1 passes inside lax.scan (keeps the compiled graph O(1) in
    # iteration count), plus one final pass returning the full solution --
    # ``iterations`` linearise+solve passes total, matching the paper.
    x_last, aux = jax.lax.scan(body, x_init, None, length=iterations - 1)
    grid = grid_lqt_from_nonlinear(
        model, ts, y, x_last, divergence_correction=divergence_correction,
        measurement_mask=measurement_mask, prior=prior,
        linearization=linearization)
    sol = solver(grid)
    if not track_costs:
        return sol, None, None
    costs, steps = aux
    trace = jnp.concatenate([costs, cost_of(sol.x)[None]], axis=0)
    step_norms = jnp.concatenate(
        [steps, step_norm(sol.x, x_last)[None]], axis=0)
    return sol, trace, step_norms


def iterated_map(
    model: NonlinearSDE,
    ts: jnp.ndarray,
    y: jnp.ndarray,
    *,
    iterations: int = 5,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    divergence_correction: bool = False,
    x_init: jnp.ndarray | None = None,
    measurement_mask: Optional[jnp.ndarray] = None,
):
    """Deprecated shim: use ``Estimator(model, method=..., options=
    IteratedOptions(...)).solve(Problem.single(...))`` instead."""
    warnings.warn(
        "iterated_map is deprecated; use repro.core.Estimator with "
        "IteratedOptions and Problem.single (see docs/MIGRATION.md)",
        DeprecationWarning, stacklevel=2)
    from .estimator import Estimator, Problem, legacy_options

    est = Estimator(model, method=method,
                    options=legacy_options(
                        model, method, nsub=nsub, mode=mode,
                        iterations=iterations,
                        divergence_correction=divergence_correction))
    return est.solve(Problem.single(model, ts, y,
                                    measurement_mask=measurement_mask,
                                    x_init=x_init))
