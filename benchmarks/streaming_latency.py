"""Streaming-latency benchmark: ``StreamingEngine`` window latency
percentiles and track throughput.

The serving question for the STREAMING engine is not drain throughput of
whole records but the freshness of a fixed-lag estimate: when a client
pushes measurements, how long until the window containing them is
re-solved?  This drives a deterministic multi-track workload (fixed seed;
every track pushes ``chunk``-interval pieces round-robin, the engine
drains between rounds so windows from different tracks batch into shared
waves) twice -- a warmup pass that compiles the per-bucket executables,
then a measured pass on fresh tracks running entirely on cache hits --
and reports tracks/sec and windows/sec (measured pass) plus the p50/p99
of the ``stream.window_latency_seconds`` obs histogram (push-to-solved
wall time per window, diffed per scenario so each row reports its own
measured pass only).

Three rows:

  stream/fixedlag/*  in-order pushes, fixed lag (the PR-7 baseline path)
  stream/late/*      10% of measurements delivered one round late into a
                     ``reorder_slack`` engine -- reports the same latency
                     percentiles plus merge/drop accounting for the
                     out-of-order path
  stream/adaptive/*  ``committed_error_target`` engine self-tuning lag in
                     ``[lag_min, lag_max]`` -- reports the final lag and
                     adjustment count

    PYTHONPATH=src python benchmarks/streaming_latency.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np

_LAT = "stream.window_latency_seconds"


def _lat_counts():
    """Snapshot of the window-latency histogram bucket counts."""
    import repro.obs as obs

    if not obs.enabled():
        return None
    h = obs.histogram(_LAT)
    return list(h.counts)


def _lat_percentiles(before):
    """p50/p99 of the latency recorded since ``before`` (count diff)."""
    import repro.obs as obs

    if before is None or not obs.enabled():
        return None
    h = obs.histogram(_LAT)
    diff = [a - b for a, b in zip(h.counts, before)]
    total = sum(diff)
    if total <= 0:
        return None

    def pct(q):
        target = q * total
        seen = 0
        for i, c in enumerate(diff):
            if seen + c >= target and c:
                lo = h.edges[i - 1] if i > 0 else 0.0
                hi = h.edges[i] if i < len(h.edges) else h.max
                return lo + (target - seen) / c * (hi - lo)
            seen += c
        return h.max

    return pct(0.5), pct(0.99)


def _stream_pass(engine, ts, tracks_y, chunk):
    """Round-robin the tracks' chunks through the engine; returns
    (tracks, windows_solved)."""
    tids = [engine.open_track(ts[0]) for _ in tracks_y]
    N = tracks_y[0].shape[0]
    windows = 0
    for i in range(0, N, chunk):
        for tid, y in zip(tids, tracks_y):
            k = min(chunk, N - i)
            engine.push(tid, ts[i + 1:i + 1 + k], y[i:i + k])
        windows += engine.run()
    for tid in tids:
        engine.close(tid)
    return len(tids), windows


def _late_pass(engine, ts, tracks_y, chunk, late_frac, seed):
    """Round-robin pass where ``late_frac`` of each track's measurements
    are held back and re-offered one round late, merged in time order with
    the next chunk.  Returns (tracks, windows, offered, merge summary)."""
    rng = np.random.default_rng(seed)
    tids = [engine.open_track(ts[0]) for _ in tracks_y]
    N = tracks_y[0].shape[0]
    held = [rng.random(N) < late_frac for _ in tracks_y]
    windows = offered = 0
    totals = {"merged": 0, "dropped_late": 0}
    for i in range(0, N + chunk, chunk):
        rnd = slice(i, min(i + chunk, N))            # this round's chunk
        prev = slice(max(0, i - chunk), max(0, i))   # last round's holds
        for tid, y, h in zip(tids, tracks_y, held):
            idx = np.concatenate([
                np.nonzero(h[prev])[0] + prev.start,
                np.nonzero(~h[rnd])[0] + rnd.start,
            ])
            idx.sort()
            if not idx.size:
                continue
            res = engine.push(tid, ts[idx + 1], y[idx])
            offered += idx.size
            totals["merged"] += res["merged"]
            totals["dropped_late"] += res["dropped_late"]
        windows += engine.run()
    for tid in tids:
        engine.close(tid)
    return len(tids), windows, offered, totals


def run(smoke=False, seed=0):
    import repro.obs as obs
    from repro.configs.wiener_velocity import WienerVelocityConfig
    from repro.serving import StreamingEngine

    model = WienerVelocityConfig(p0=1.0).model()
    if smoke:
        batch, n_tracks, N, chunk, lag = 4, 4, 40, 10, 16
    else:
        batch, n_tracks, N, chunk, lag = 8, 16, 200, 20, 64
    rng = np.random.default_rng(seed)
    ny = np.asarray(model.H).shape[0]
    ts = np.linspace(0.0, N / 32.0, N + 1, dtype=np.float32)
    tracks_y = [rng.standard_normal((N, ny)).astype(np.float32)
                for _ in range(n_tracks)]
    rows = []

    def derived_common(tracks, windows, dt, before):
        d = (f"tracks_per_sec={tracks / dt:.1f}"
             f",windows_per_sec={windows / dt:.1f}")
        pcts = _lat_percentiles(before)
        if pcts is not None:
            d += f",p50_ms={pcts[0] * 1e3:.2f},p99_ms={pcts[1] * 1e3:.2f}"
        if obs.enabled():
            d += f",waste={obs.gauge('stream.padding_waste').value:.3f}"
        return d

    # --- fixed-lag, in-order (PR-7 baseline path) ----------------------
    engine = StreamingEngine(model, lag=lag, batch=batch)
    _stream_pass(engine, ts, tracks_y, chunk)   # warmup: compiles buckets
    before = _lat_counts()
    t0 = time.perf_counter()
    tracks, windows = _stream_pass(engine, ts, tracks_y, chunk)
    dt = time.perf_counter() - t0
    rows.append({
        "name": f"stream/fixedlag/B{batch}_T{n_tracks}_L{lag}",
        "us_per_call": dt / windows * 1e6,
        "derived": derived_common(tracks, windows, dt, before),
    })

    # --- 10% late pushes into a reorder-slack engine -------------------
    # lag alone is shorter than a round, so one-round-late data survives
    # only because eviction is delayed by ``reorder_slack`` intervals.
    late_lag, slack, late_frac = max(2, chunk // 2), chunk, 0.10
    engine = StreamingEngine(model, lag=late_lag, batch=batch,
                             reorder_slack=slack)
    _late_pass(engine, ts, tracks_y, chunk, late_frac, seed + 1)  # warmup
    before = _lat_counts()
    t0 = time.perf_counter()
    tracks, windows, offered, totals = _late_pass(
        engine, ts, tracks_y, chunk, late_frac, seed + 1)
    dt = time.perf_counter() - t0
    rows.append({
        "name": f"stream/late/B{batch}_T{n_tracks}_L{late_lag}_S{slack}",
        "us_per_call": dt / windows * 1e6,
        "derived": (derived_common(tracks, windows, dt, before)
                    + f",late_merged={totals['merged']}"
                    + f",drop_rate={totals['dropped_late'] / offered:.4f}"),
    })

    # --- adaptive lag --------------------------------------------------
    # Self-tuning run: the engine observes the change in about-to-be-
    # evicted states and walks lag toward the cheapest value meeting the
    # committed-error target; derived records where it settled.  Uses a
    # dt=0.1 grid (the model's nominal rate): the fixedlag rows' finer
    # grid decays too slowly per interval for any feasible lag to meet a
    # meaningful target.
    ts_a = np.linspace(0.0, N / 10.0, N + 1, dtype=np.float32)
    engine = StreamingEngine(model, lag=max(2, chunk // 2), batch=batch,
                             committed_error_target=0.5,
                             lag_min=2, lag_max=lag)
    before = _lat_counts()
    t0 = time.perf_counter()
    tracks, windows = _stream_pass(engine, ts_a, tracks_y, chunk)
    dt = time.perf_counter() - t0
    rows.append({
        "name": f"stream/adaptive/B{batch}_T{n_tracks}_Lmax{lag}",
        "us_per_call": dt / windows * 1e6,
        "derived": (derived_common(tracks, windows, dt, before)
                    + f",final_lag={engine.lag}"
                    + f",lag_adjustments={engine.lag_adjustments}"),
    })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI bit-rot check)")
    args = ap.parse_args()
    import repro.obs as obs
    obs.enable()
    for r in run(smoke=args.smoke):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
