"""starcoder2-15b: 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152,
GQA + RoPE, non-gated (gelu) MLP [arXiv:2402.19173]."""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        d_ff=24576, vocab_size=49152, mlp_type="plain", act="gelu", remat_group=8)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="starcoder2-15b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)
