"""Sequential baselines (the paper's comparison algorithms, section 5).

These are the O(T)-span algorithms the parallel methods are benchmarked
against:

* :func:`sequential_backward`   -- Euler on the Riccati ODEs (15) (``euler``
  mode) or exact information-form steps (``discrete`` mode); equivalent to
  the Kalman-Bucy filter (22) in original time (section 2.5).
* :func:`sequential_rts`        -- + forward Euler of eq. (18): the
  sequential continuous-time RTS smoother.
* :func:`sequential_two_filter` -- + forward HJB (51) integration and the
  two-filter combination (48).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .combine import apply_element_to_value, elem_min_initial, lqt_combine
from .elements import _lin_term, identity_element, one_step_elements
from .types import GridLQT, LQTElement, MAPSolution, ValueFn


def _stack_with_terminal(head, terminal):
    return jax.tree_util.tree_map(
        lambda h, t: jnp.concatenate([h, t[None]], axis=0), head, terminal)


def sequential_backward(grid: GridLQT, mode: str = "euler") -> ValueFn:
    """S(tau_j), v(tau_j) for j = 0..N (reversed time), O(N) span."""
    from .elements import _ode_step_backward

    term = ValueFn(grid.S_T, grid.v_T)
    lin = _lin_term(grid)

    if mode == "discrete":
        elems = one_step_elements(grid)

        def step(carry, e):
            nxt = apply_element_to_value(e, carry)
            return nxt, nxt

        _, head = jax.lax.scan(step, term, elems, reverse=True)
        return _stack_with_terminal(head, term)

    def step(carry, inp):
        dtk, Fk, ck, Hk, rk, Qk, Rik, yk, lk = inp
        HtRi = Hk.T @ Rik

        def derivs(sv):
            S, v = sv
            dS = S @ Qk @ S - S @ Fk - Fk.T @ S - HtRi @ Hk
            dv = S @ (Qk @ v + ck) - Fk.T @ v - HtRi @ (yk - rk) + lk
            return (dS, dv)

        Sn, vn = _ode_step_backward(derivs, tuple(carry), dtk, mode)
        Sn = 0.5 * (Sn + Sn.T)
        nxt = ValueFn(Sn, vn)
        return nxt, nxt

    _, head = jax.lax.scan(
        step, term,
        (grid.dt, grid.F, grid.c, grid.H, grid.r, grid.Q, grid.Rinv,
         grid.y, lin),
        reverse=True)
    return _stack_with_terminal(head, term)


def affine_recovery_maps(grid: GridLQT, values: ValueFn, mode: str = "euler"):
    """Per-substep affine maps phi(tau_{j+1}) = Phi_j phi(tau_j) + beta_j.

    ``euler`` mode: eq. (18)-(19) with left-point values,
    ``discrete`` mode: exact argmin step
    ``z* = (I + C_j S_{j+1})^{-1} (A_j phi + b_j + C_j v_{j+1})``.
    """
    if mode == "discrete":
        e = one_step_elements(grid)
        S1 = values.S[1:]
        v1 = values.v[1:]
        I = jnp.eye(grid.nx, dtype=grid.F.dtype)
        M = I + e.C @ S1
        rhs = jnp.concatenate(
            [e.A, (e.b + jnp.einsum("kij,kj->ki", e.C, v1))[..., None]],
            axis=-1)
        sol = jnp.linalg.solve(M, rhs)
        return sol[..., :-1], sol[..., -1]

    S0 = values.S[:-1]
    v0 = values.v[:-1]
    dt = grid.dt[:, None, None]
    I = jnp.eye(grid.nx, dtype=grid.F.dtype)
    Fbar = grid.F - grid.Q @ S0
    Phi = I + dt * Fbar
    beta = grid.dt[:, None] * (jnp.einsum("kij,kj->ki", grid.Q, v0) + grid.c)
    return Phi, beta


def sequential_rts(grid: GridLQT, mode: str = "euler") -> MAPSolution:
    """Sequential continuous-time RTS smoother (backward (15) + forward (18))."""
    values = sequential_backward(grid, mode)
    Phi, beta = affine_recovery_maps(grid, values, mode)
    phi0 = jnp.linalg.solve(values.S[0], values.v[0])

    def step(phi, inp):
        P, b = inp
        nxt = P @ phi + b
        return nxt, nxt

    _, tail = jax.lax.scan(step, phi0, (Phi, beta))
    phi = jnp.concatenate([phi0[None], tail], axis=0)
    return MAPSolution(
        x=jnp.flip(phi, axis=0),
        S=jnp.flip(values.S, axis=0),
        v=jnp.flip(values.v, axis=0))


def two_filter_combine(fwd: LQTElement, S: jnp.ndarray, v: jnp.ndarray):
    """Eq. (48): phi* = (I + Cbar S)^{-1} (bbar + Cbar v) (+ covariance)."""
    I = jnp.broadcast_to(jnp.eye(S.shape[-1], dtype=S.dtype), S.shape)
    M = I + fwd.C @ S
    rhs = jnp.concatenate(
        [(fwd.b + (fwd.C @ v[..., None])[..., 0])[..., None], fwd.C],
        axis=-1)
    sol = jnp.linalg.solve(M, rhs)
    phi = sol[..., 0]
    cov = sol[..., 1:]
    return phi, 0.5 * (cov + jnp.swapaxes(cov, -1, -2))


def sequential_two_filter(
    grid: GridLQT, mode: str = "euler", jitter: float = 1e-9,
) -> MAPSolution:
    """Sequential two-filter smoother.

    Integrates the forward HJB (51) from the identity element, then folds
    the free-initial-condition minimisation (eqs. 39/50) pointwise, after
    which (b, C) are the backward-time filter mean/covariance (section 4.3)
    and eq. (48) recovers the trajectory.  ``jitter`` regularises the
    near-singular early-time J (few measurements seen yet).
    """
    values = sequential_backward(grid, mode)
    lin = _lin_term(grid)
    e0 = identity_element(grid.nx, grid.F.dtype)

    if mode == "discrete":
        elems = one_step_elements(grid)

        def step(carry, e):
            nxt = lqt_combine(carry, e)
            return nxt, nxt

        _, fwd = jax.lax.scan(step, e0, elems)
    else:
        def step(carry, inp):
            A, b, C, eta, J = carry
            dtk, Fk, ck, Hk, rk, Qk, Rik, yk, lk = inp
            HtRi = Hk.T @ Rik
            CHtRi = C @ HtRi
            innov = HtRi @ (yk - rk)
            dA = -CHtRi @ (Hk @ A) + Fk @ A
            db = C @ innov + Fk @ b + ck - CHtRi @ (Hk @ b) - C @ lk
            dC = -CHtRi @ (Hk @ C) + Qk + Fk @ C + C @ Fk.T
            deta = A.T @ (innov - HtRi @ (Hk @ b) - lk)
            dJ = A.T @ HtRi @ (Hk @ A)
            Cn = C + dtk * dC
            Jn = J + dtk * dJ
            nxt = LQTElement(
                A + dtk * dA, b + dtk * db, 0.5 * (Cn + Cn.T),
                eta + dtk * deta, 0.5 * (Jn + Jn.T))
            return nxt, nxt

        _, fwd = jax.lax.scan(
            step, e0,
            (grid.dt, grid.F, grid.c, grid.H, grid.r, grid.Q, grid.Rinv,
             grid.y, lin))

    # Fold the free-initial-condition minimisation pointwise (eq. 39/50).
    folded = jax.vmap(lambda e: elem_min_initial(e, jitter=jitter))(fwd)
    phi_tail, cov_tail = two_filter_combine(
        folded, values.S[1:], values.v[1:])
    phi0 = jnp.linalg.solve(values.S[0], values.v[0])
    cov0 = jnp.linalg.inv(values.S[0])
    phi = jnp.concatenate([phi0[None], phi_tail], axis=0)
    cov = jnp.concatenate([cov0[None], cov_tail], axis=0)
    return MAPSolution(
        x=jnp.flip(phi, axis=0),
        S=jnp.flip(values.S, axis=0),
        v=jnp.flip(values.v, axis=0),
        cov=jnp.flip(cov, axis=0))
