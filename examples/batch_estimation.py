"""Batched multi-trajectory estimation: the request axis in ~50 lines.

One ``Estimator`` serves every layout: a stack of independent
Wiener-velocity problems as one compiled program (``Problem.stacked``), a
ragged mix of record lengths via pad-and-bucket (``Problem.ragged``, with
the padding report on the solutions), and the same workload through the
serving-style ``TrajectoryEngine``.

    PYTHONPATH=src python examples/batch_estimation.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.configs.wiener_velocity import WienerVelocityConfig
from repro.core import (
    Estimator, ParallelOptions, Problem, cache_stats, simulate_linear,
    time_grid,
)
from repro.serving import TrajectoryEngine

cfg = WienerVelocityConfig(p0=1.0)
model = cfg.model()
T, n = 64, 10
est = Estimator(model, method="parallel_rts",
                options=ParallelOptions(nsub=n, mode="discrete"))

# --- stacked batch: B records sharing one time grid -> ONE compiled solve
B = 16
ts = time_grid(cfg.t0, cfg.tf, T * n)
ys = jnp.stack([simulate_linear(model, ts, jax.random.PRNGKey(i))[1]
                for i in range(B)])
sol = est.solve(Problem.stacked(model, ts, ys))
ref = est.solve(Problem.single(model, ts, ys[0]))
gap = float(jnp.abs(sol.x[0] - ref.x).max())
print(f"stacked batch     : {sol.x.shape} (batch, time, state)")
print(f"per-record OM cost: {np.asarray(sol.cost).round(1)}")
print(f"batched vs single solve max gap: {gap:.2e}")
assert gap < 1e-9

# --- ragged lengths: pad-and-bucket keeps the executable count tiny
lengths = [130, 250, 460, 250, 900, 130]
records = []
for i, N in enumerate(lengths):
    ts_i = time_grid(cfg.t0, cfg.tf * N / (T * n), N)
    _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(100 + i))
    records.append((np.asarray(ts_i), np.asarray(y_i)))
sols = est.solve(Problem.ragged(model, records))
report = sols[0].padding
print(f"ragged lengths    : {lengths}")
print(f"returned lengths  : {[s.x.shape[0] - 1 for s in sols]}")
print(f"padding report    : buckets={[(b.n_pad, b.records, b.batch) for b in report.buckets]}"
      f" interval_util={report.interval_utilisation:.2f}"
      f" row_util={report.row_utilisation:.2f}")
print(f"executable cache  : {cache_stats()}")

# --- serving engine: queue + submit/collect with fixed-batch waves
engine = TrajectoryEngine(model, batch=4, method="parallel_rts",
                          options=ParallelOptions(nsub=n, mode="discrete"))
tickets = [engine.submit(ts_i, y_i) for ts_i, y_i in records]
engine.run()
done = engine.collect()
print(f"engine solved     : {len(done)} requests in {engine.waves} waves "
      f"({engine.recycled_rows} rows recycled)")
assert [t for t, _ in done] == tickets
print("OK")
