"""Gradient compression for the cross-pod data-parallel all-reduce.

bf16 all-reduce with fp32 ERROR FEEDBACK: each step the residual of the
previous compression is added back before quantising, so the compression
error does not accumulate (it is re-injected and eventually transmitted) --
the standard EF-SGD construction.  Halves the gradient-reduction bytes on
the slowest (inter-pod DCN/ICI) links, directly attacking the collective
roofline term of training cells.

Used with an explicitly shard_mapped data-parallel step (GSPMD's implicit
psum cannot be intercepted); see tests/test_distributed.py for the 8-device
equivalence test against uncompressed training.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_state(grads_like) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(grads, err, axis_name: str) -> Tuple[Any, Any]:
    """bf16 psum with fp32 error feedback.

    Returns (mean_grads_f32, new_err).  Call INSIDE shard_map over the
    data-parallel axis with per-shard (unreduced) gradients.
    """
    # psum of 1 == the axis size; jax.lax.axis_size is not available on
    # every supported jax release, psum works inside shard_map on all.
    size = jax.lax.psum(1, axis_name)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q = target.astype(jnp.bfloat16)
        new_e = target - q.astype(jnp.float32)
        summed = jax.lax.psum(q.astype(jnp.float32), axis_name)
        return summed / size, new_e

    out = jax.tree_util.tree_map(one, grads, err)
    mean = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return mean, new_err


def make_compressed_dp_step(loss_fn, optimizer_update, mesh,
                            axis_name: str = "data"):
    """Builds a shard_mapped DP train step with compressed gradient sync.

    loss_fn(params, batch) -> scalar;  optimizer_update(grads, opt, params)
    -> (params, opt).  Params/opt replicated; batch sharded over
    ``axis_name``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def step(params, opt, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        mean_loss = jax.lax.pmean(loss, axis_name)
        mean_grads, new_err = compressed_psum(grads, err, axis_name)
        new_params, new_opt = optimizer_update(mean_grads, opt, params)
        return new_params, new_opt, new_err, mean_loss

    rep = P()
    batch_spec = P(axis_name)
    return shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_rep=False)
