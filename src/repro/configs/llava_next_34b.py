"""llava-next-34b backbone: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 [hf:llava-hf/llava-v1.6, 34B-class backbone].

VLM: the anyres tiling vision frontend is a STUB per the assignment --
``input_specs`` provides precomputed patch+text embeddings for train and
prefill; decode uses the token path (embedding table present).
"""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=20480, vocab_size=64000,
        input_mode="embeddings", remat_group=10)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="llava-next-34b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128)
