"""Validate the analytic cost model against XLA on loop-free lowerings.

``compiled.cost_analysis()`` is only trustworthy when the HLO has no while
loops (bodies are counted once), so the validation configs unroll layers
and use chunk sizes >= seq_len.  Agreement gate: 20% on flops -- the
analytic model ignores softmax/norm transcendentals and minor elementwise
traffic, XLA ignores nothing; the roofline (benchmarks/roofline.py) uses
the analytic numbers for looped production lowerings.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.flops import step_cost, xla_cost_analysis
from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer
from repro.train.optimizer import adamw_init
from repro.train.trainer import make_train_step

CASES = {
    "dense-gqa": ModelConfig(
        name="v-dense", family="dense", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=704, vocab_size=512,
        unroll_layers=True),
    "plain-mlp": ModelConfig(
        name="v-plain", family="dense", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=1024, vocab_size=512,
        mlp_type="plain", act="gelu", unroll_layers=True),
    "moe": ModelConfig(
        name="v-moe", family="moe", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
        moe_experts=4, moe_topk=2, moe_capacity_factor=1.0,
        unroll_layers=True),
    "ssm": ModelConfig(
        name="v-ssm", family="ssm", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=0, mlp_type="none",
        mixer="ssm", vocab_size=512, ssm_state=32, ssm_head_dim=32,
        ssm_chunk=1024, unroll_layers=True),
}


@pytest.mark.slow
@pytest.mark.parametrize("case", sorted(CASES))
def test_analytic_flops_match_xla(case):
    cfg = CASES[case]
    shape = ShapeConfig("val", "train", seq_len=128, global_batch=2)
    tcfg = TrainConfig()
    step = make_train_step(cfg, tcfg)
    batch = {
        "tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32),
        "labels": jax.ShapeDtypeStruct((2, 128), jnp.int32),
    }
    params = jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: adamw_init(
        transformer.init(cfg, jax.random.PRNGKey(0))))
    compiled = jax.jit(step).lower(params, opt, batch).compile()
    xla_flops = xla_cost_analysis(compiled)["flops"]
    analytic = step_cost(cfg, shape, chips=1).flops
    ratio = analytic / xla_flops
    assert 0.8 < ratio < 1.25, (case, analytic, xla_flops, ratio)
