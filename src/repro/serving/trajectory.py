"""Trajectory-estimation serving engine: MAP solves as a batched service.

``TrajectoryEngine`` is the estimation-workload sibling of
:class:`~repro.serving.engine.ServeEngine`: it serves
:class:`~repro.core.Problem` solves through one
:class:`~repro.core.Estimator`.  The production tricks:

* **fixed-batch padding** -- every wave is exactly ``batch`` rows, so each
  bucket length compiles ONE executable, reused forever (the executable
  cache lives in :mod:`repro.core.estimator`);
* **pad-and-bucket** -- ragged record lengths are padded to power-of-two
  block counts with masked measurements (exact, see
  :mod:`repro.core.padding`);
* **row recycling / continuous batching** -- short waves are topped up by
  recycling a live row, and the queue is drained in FIFO waves grouped by
  bucket so one submit/collect cycle serves any mix of lengths;
* **optional mesh sharding** -- pass a mesh (a ``jax.sharding.Mesh`` or
  a :class:`repro.distributed.MeshSpec`) and each wave is sharded over
  the mesh's batch axis, spreading requests across devices; with
  ``method="distributed"`` the mesh's time axis additionally shards the
  associative scan of every solve (2-D time x batch layout).

API: ``submit(ts, y) -> ticket``; ``step()`` solves one wave; ``collect()``
pops finished ``(ticket, Solution)`` pairs; ``estimate(records)`` is the
synchronous convenience wrapper.

The solver configuration is the Estimator's: pass ``method=`` plus the
method's options dataclass (e.g. ``ParallelOptions(nsub=10,
mode="discrete")``, or ``IteratedOptions(...)`` for nonlinear models).
The pre-redesign kwargs (``nsub``/``mode``/``iterations``/
``divergence_correction``) are still accepted with a
``DeprecationWarning``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.estimator import Estimator, Problem, legacy_options
from repro.core.padding import bucket_length, pad_record, slice_solution
from repro.core.sde import LinearSDE, NonlinearSDE
from repro.core.types import Solution


@dataclasses.dataclass
class _Pending:
    ticket: int
    ts: np.ndarray
    y: np.ndarray
    n_pad: int
    submit_t: float = 0.0   # perf_counter at submit; queue-to-collect latency


class TrajectoryEngine:
    """Queued, batched MAP-estimation service for one model.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`.
      batch: fixed wave size (compiled batch).  With a mesh it must be
        divisible by the mesh's ``batch_axis`` size.
      method: registered method name; ``options`` its options dataclass
        (``None`` = method defaults) -- both forwarded to the underlying
        :class:`~repro.core.Estimator`.
      bucket_sizes: optional explicit padded-length buckets (multiples of
        the method's block size); default is power-of-two block counts.
      mesh: optional ``jax.sharding.Mesh`` or
        :class:`repro.distributed.MeshSpec` (the unified mesh entry
        point) for batch-axis sharding; with ``method="distributed"``
        the mesh's time axis additionally shards the scan itself.
    """

    def __init__(
        self,
        model: Union[LinearSDE, NonlinearSDE],
        *,
        batch: int = 8,
        method: str = "parallel_rts",
        options=None,
        bucket_sizes: Optional[Sequence[int]] = None,
        mesh=None,
        batch_axis: str = "data",
        **legacy,
    ):
        if legacy:
            allowed = {"nsub", "mode", "iterations", "divergence_correction"}
            unknown = set(legacy) - allowed
            if unknown:
                raise TypeError(
                    f"unexpected keyword arguments: {sorted(unknown)}")
            if options is not None:
                raise TypeError(
                    "pass either options=... or the legacy kwargs "
                    f"{sorted(legacy)}, not both")
            warnings.warn(
                f"TrajectoryEngine kwargs {sorted(legacy)} are deprecated; "
                "pass the method's options dataclass via options= "
                "(see docs/MIGRATION.md)", DeprecationWarning, stacklevel=2)
            options = legacy_options(model, method, **legacy)
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.estimator = Estimator(model, method=method, options=options,
                                   mesh=mesh, batch_axis=batch_axis)
        shard = self.estimator._batch_shard_size(
            self.estimator._resolved_mesh())
        if batch % shard:
            raise ValueError(
                f"batch {batch} not divisible by mesh batch axis size "
                f"{shard}")
        self.model = model
        self.batch = batch
        self.bucket_sizes = bucket_sizes

        self._queue: Deque[_Pending] = collections.deque()
        self._done: Dict[int, Solution] = {}
        self._next_ticket = 0
        self.waves = 0            # compiled-batch solves issued
        self.recycled_rows = 0    # padding rows recycled into short waves

    # -- submit / collect ---------------------------------------------------

    def submit(self, ts: np.ndarray, y: np.ndarray) -> int:
        """Enqueue one record; returns a ticket redeemable at collect()."""
        ts = np.asarray(ts)
        y = np.asarray(y)
        if y.ndim != 2 or y.shape[0] < 1:
            raise ValueError(
                f"y must be (N, ny) with N >= 1, got shape {y.shape}")
        if ts.shape != (y.shape[0] + 1,):
            raise ValueError(
                f"ts must be (N+1,) = {(y.shape[0] + 1,)}, got {ts.shape}")
        ticket = self._next_ticket
        self._next_ticket += 1
        n_pad = bucket_length(y.shape[0], self.estimator.block_size,
                              self.bucket_sizes)
        self._queue.append(
            _Pending(ticket, ts, y, n_pad, time.perf_counter()))
        if obs.enabled():
            obs.inc("engine.submitted")
            obs.set_gauge("engine.queue_depth", len(self._queue))
        return ticket

    def pending(self) -> int:
        return len(self._queue)

    def collect(self) -> List[Tuple[int, Solution]]:
        """Pop all finished (ticket, solution) pairs, ticket order."""
        out = sorted(self._done.items())
        self._done.clear()
        return out

    # -- wave processing ----------------------------------------------------

    def _take_wave(self) -> List[_Pending]:
        """FIFO wave: the oldest request fixes the bucket; later same-bucket
        requests top the wave up to ``batch`` (others keep their place).
        Scanning stops as soon as the wave is full, so draining Q queued
        requests is O(Q), not O(Q^2/batch)."""
        n_pad = self._queue[0].n_pad
        wave: List[_Pending] = []
        keep: Deque[_Pending] = collections.deque()
        while self._queue and len(wave) < self.batch:
            req = self._queue.popleft()
            if req.n_pad == n_pad:
                wave.append(req)
            else:
                keep.append(req)
        keep.extend(self._queue)           # untouched tail, order preserved
        self._queue = keep
        return wave

    def step(self) -> int:
        """Solve one fixed-size wave; returns the number of requests
        completed (0 if the queue is empty).

        With ``repro.obs`` enabled each wave reports: occupancy (real
        rows / batch), padding waste (padded vs real intervals), queue
        depth, and per-record submit-to-done latency percentiles
        (``engine.record_latency_seconds``)."""
        if not self._queue:
            return 0
        with obs.trace_span("engine.step"):
            wave = self._take_wave()
            n_pad = wave[0].n_pad
            padded = [pad_record(r.ts, r.y, n_pad) for r in wave]
            rows = padded + [padded[0]] * (self.batch - len(padded))
            self.recycled_rows += self.batch - len(padded)
            ts_b = jnp.asarray(np.stack([r[0] for r in rows]))
            ys_b = jnp.asarray(np.stack([r[1] for r in rows]))
            mask_b = jnp.asarray(np.stack([r[2] for r in rows]))
            sol = self.estimator.solve(
                Problem.stacked(self.model, ts_b, ys_b,
                                measurement_mask=mask_b))
            self.waves += 1
            for row, req in enumerate(wave):
                self._done[req.ticket] = slice_solution(
                    sol, row, req.y.shape[0])
            if obs.enabled():
                self._record_wave_metrics(wave, n_pad)
        return len(wave)

    def _record_wave_metrics(self, wave: List[_Pending],
                             n_pad: int) -> None:
        now = time.perf_counter()
        real = sum(r.y.shape[0] for r in wave)
        solved = n_pad * self.batch
        obs.inc("engine.waves")
        obs.inc("engine.completed", len(wave))
        obs.inc("engine.recycled_rows", self.batch - len(wave))
        obs.inc("engine.real_intervals", real)
        obs.inc("engine.padded_intervals", solved)
        obs.record("engine.wave_occupancy", len(wave) / self.batch,
                   buckets=[i / 20 for i in range(21)])
        # cumulative padding waste: fraction of solved intervals that were
        # padding or recycled rows (0 = perfect packing)
        c = obs.REGISTRY.counter
        total_real = c("engine.real_intervals").value
        total_solved = c("engine.padded_intervals").value
        if total_solved:
            obs.set_gauge("engine.padding_waste",
                          1.0 - total_real / total_solved)
        obs.set_gauge("engine.queue_depth", len(self._queue))
        for req in wave:
            obs.record("engine.record_latency_seconds", now - req.submit_t)

    def run(self) -> int:
        """Drain the queue; returns the total number of requests solved.

        With ``repro.obs`` enabled, sets ``engine.tracks_per_sec`` (drain
        throughput of this call)."""
        total = 0
        t0 = time.perf_counter()
        with obs.trace_span("engine.run"):
            while self._queue:
                total += self.step()
        dt = time.perf_counter() - t0
        if total and dt > 0:
            obs.set_gauge("engine.tracks_per_sec", total / dt)
        return total

    # -- synchronous convenience --------------------------------------------

    def estimate(
        self, records: Sequence[Tuple[np.ndarray, np.ndarray]],
    ) -> List[Solution]:
        """Submit ``(ts, y)`` records, drain, return solutions in order."""
        tickets = [self.submit(ts, y) for ts, y in records]
        self.run()
        got = dict(self.collect())
        return [got[t] for t in tickets]
