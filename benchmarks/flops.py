"""Analytic FLOP / HBM-byte model for every dry-run cell.

WHY THIS EXISTS: ``compiled.cost_analysis()`` counts ``while``-loop bodies
ONCE, and this framework deliberately scans over layers (and attention /
SSD chunks) to keep 60-layer compiles tractable -- so the XLA numbers
undercount by ~num_layers x.  The roofline therefore uses this analytic
model, which is VALIDATED against XLA on loop-free lowerings
(``unroll_layers=True``, chunk sizes >= seq) in
tests/test_roofline_model.py: agreement within ~15% on dense/GQA/MoE/SSM
configs.  Collective bytes are NOT modelled here -- they come from the
loop-aware structural HLO parse in launch/dryrun.py (measured, per cell).

Conventions:
* all counts are GLOBAL per step (divide by chip count for per-device);
* a matmul of (m, k) x (k, n) counts 2 m k n flops;
* backward = 2x forward; full-layer remat adds +1x forward for layers
  under ``jax.checkpoint``;
* the baseline chunked attention computes the full rectangular logits
  (causal masking wastes ~half) -- ``causal_skip`` halves the logit term;
* MoE compute is capacity-based: the dense (E, cap, D) buffers do the
  padded work, so capacity (not routed tokens) is what burns flops.
"""
from __future__ import annotations

import dataclasses

from repro.config import ModelConfig, ShapeConfig

BYTES = {"bfloat16": 2, "float32": 4}


def xla_cost_analysis(compiled) -> dict:
    """Normalise ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a per-computation list/tuple of dicts (entry 0 is
    the entry computation); newer jax returns the dict directly.  Returns
    ``{}`` when the backend reports nothing.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    flops: float              # global flops per step
    weight_bytes: float       # per-device weight traffic per step
    act_bytes: float          # per-device activation traffic per step
    kv_bytes: float           # per-device attention KV traffic per step
    flops_detail: dict

    @property
    def hbm_bytes(self) -> float:
        return self.weight_bytes + self.act_bytes + self.kv_bytes


def _attn_flops(cfg: ModelConfig, T: float, ctx: float,
                causal_skip: bool) -> dict:
    hd = cfg.hd
    proj = 2 * T * cfg.d_model * hd * (2 * cfg.num_heads
                                       + 2 * cfg.num_kv_heads)
    eff_ctx = min(ctx, cfg.window) if cfg.window else ctx
    # triangular schedule halves the causal self-attention logit sweep
    logits_ctx = eff_ctx * (0.5 if causal_skip else 1.0)
    score_pv = 4 * T * logits_ctx * cfg.num_heads * hd
    return {"attn_proj": proj, "attn_score_pv": score_pv}


def _ssm_flops(cfg: ModelConfig, T: float, seq: float) -> dict:
    D = cfg.d_model
    din = cfg.ssm_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    H, P, S = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = min(cfg.ssm_chunk, int(seq))
    proj = 2 * T * D * (2 * din + 2 * gs + H) + 2 * T * din * D
    conv = 2 * T * (din + 2 * gs) * cfg.ssm_conv
    # chunked SSD: Gmat (2 T Q G S) + y_intra (2 T Q H P)
    #             + elements/inter (2 * 2 T H P S)
    ssd = (2 * T * Q * cfg.ssm_groups * S + 2 * T * Q * H * P
           + 4 * T * H * P * S)
    return {"ssm_proj": proj + conv, "ssm_scan": ssd}


def _ffn_flops(cfg: ModelConfig, T: float) -> dict:
    mults = 3 if cfg.mlp_type == "gated" else 2
    if cfg.is_moe:
        cap_tokens = min(cfg.moe_topk * cfg.moe_capacity_factor,
                         float(cfg.moe_experts)) * T
        return {
            "moe_experts": 2 * cap_tokens * cfg.d_model * cfg.d_ff * mults,
            "moe_router": 2 * T * cfg.d_model * cfg.moe_experts,
        }
    if cfg.mlp_type == "none" or cfg.d_ff == 0:
        return {}
    return {"mlp": 2 * T * cfg.d_model * cfg.d_ff * mults}


def step_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int,
              causal_skip: bool = False,
              attn_chunk: int = 512) -> CostBreakdown:
    B, S = shape.global_batch, shape.seq_len
    dt = BYTES[cfg.dtype]
    L = cfg.num_layers

    if shape.kind in ("train", "prefill"):
        T = float(B) * S
        ctx = float(S)
    else:
        T = float(B)
        ctx = float(S)

    per_layer: dict = {}
    if cfg.mixer in ("attn", "hybrid"):
        per_layer.update(_attn_flops(
            cfg, T, ctx, causal_skip and shape.kind != "decode"))
    if cfg.mixer in ("ssm", "hybrid"):
        if shape.kind == "decode":
            H, P, Ss = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            per_layer.update({
                "ssm_proj": 2 * T * cfg.d_model
                * (2 * cfg.ssm_inner + 2 * cfg.ssm_groups * Ss + H)
                + 2 * T * cfg.ssm_inner * cfg.d_model,
                "ssm_scan": 4 * T * H * P * Ss})
        else:
            per_layer.update(_ssm_flops(cfg, T, ctx))
    per_layer.update(_ffn_flops(cfg, T))
    layer_fwd = float(sum(per_layer.values()))

    head = 2 * T * cfg.d_model * cfg.padded_vocab
    embed = 0.0  # gather, no flops

    if shape.kind == "train":
        # fwd + bwd(2x) + layer remat recompute (+ group recompute for
        # sqrt remat, see ModelConfig.remat_group)
        remat_factor = 3.0
        if cfg.remat:
            remat_factor += 1.0
            if cfg.remat_group:
                remat_factor += 1.0
        stack = L * layer_fwd * remat_factor
        head_total = 3.0 * head
        opt = 15.0 * cfg.param_count()
        total = stack + head_total + embed + opt
    else:
        stack = L * layer_fwd
        extra = 0.0
        if shape.kind == "prefill" and cfg.mixer in ("ssm", "hybrid"):
            extra = L * _ssm_flops(cfg, T, ctx)["ssm_proj"] * 0.5  # replay
        head_total = head if shape.kind == "decode" else \
            2 * float(B) * cfg.d_model * cfg.padded_vocab
        total = stack + head_total + extra

    detail = {k: v * L for k, v in per_layer.items()}
    detail["lm_head"] = head_total
    detail["_layer_fwd_one"] = layer_fwd

    # ---- per-device HBM traffic (coarse, documented) ----
    # weights: sharded over the 16-way model axis, replicated over DP
    # (dp_only policy replicates weights and spreads the batch instead).
    model_par = 16 if (chips >= 16
                       and cfg.parallel_policy != "dp_only") else 1
    N = cfg.param_count()
    weight_reads = N * dt / model_par
    if shape.kind == "train":
        # fwd + remat-fwd + bwd reads + updated write of bf16 params,
        # plus AdamW m/v/master f32 read+write ZeRO-sharded over all chips
        weight_traffic = 4.0 * weight_reads + 10.0 * N * 4 / chips
    else:
        weight_traffic = weight_reads

    T_loc = T / max(1, chips // model_par)
    act_traffic = 20.0 * T_loc * cfg.d_model * dt * L \
        * (2.0 if shape.kind == "train" else 1.0)
    kv_traffic = 0.0
    if cfg.mixer in ("attn", "hybrid"):
        eff_ctx = min(ctx, cfg.window) if cfg.window else ctx
        kv_one = 2 * eff_ctx * cfg.num_kv_heads * cfg.hd * dt
        if shape.kind == "decode":
            B_loc = B / max(1, chips // model_par)
            kv_traffic = L * B_loc * kv_one  # read cache once per step
        else:
            nq = max(1, int(S // attn_chunk))
            B_loc = B / max(1, chips // model_par)
            kv_traffic = L * B_loc * kv_one * nq \
                * (2.0 if shape.kind == "train" else 1.0)

    return CostBreakdown(
        flops=float(total),
        weight_bytes=float(weight_traffic),
        act_bytes=float(act_traffic),
        kv_bytes=float(kv_traffic),
        flops_detail=detail,
    )


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The 6*N*D / 6*N_active*D reference (2*N*D for inference cells)."""
    n = cfg.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill")
              else shape.global_batch)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens
