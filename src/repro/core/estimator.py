"""The unified estimation surface: ``Estimator.solve(Problem) -> Solution``.

One composable API replaces the old quintet of entry points
(``map_estimate`` / ``iterated_map`` / ``map_estimate_batched`` /
``map_estimate_ragged`` / ad-hoc engine plumbing):

* :class:`Problem` describes WHAT to solve -- model + time grid +
  measurements (+ optional mask / warm start), in one of three layouts
  built by :meth:`Problem.single`, :meth:`Problem.stacked` (records
  sharing a length) and :meth:`Problem.ragged` (pad-and-bucket over
  unequal lengths).
* :class:`~repro.core.options.SolverOptions` subclasses describe HOW --
  each registered method owns its options dataclass
  (:mod:`repro.core.registry`), so knobs are validated at construction
  and never leak into unrelated signatures.
* :class:`Estimator` binds (model, method, options, mesh) and compiles
  ONE executable per (problem layout, options) key, cached in the
  module-level executable cache (inspect with :func:`cache_stats`).
  ``.solve`` runs it; ``.lower`` returns the ``jax.stages.Lowered`` for
  ahead-of-time compilation.
* :class:`~repro.core.types.Solution` is the result: the MAP trajectory
  and filter information plus diagnostics (Onsager-Machlup cost,
  per-iteration cost trace for nonlinear solves, bucket/padding report
  for ragged solves).

Nonlinear models are solved with the iterated linearisation of section
4.4 (:func:`repro.core.nonlinear.iterated_solve`); wrap the inner method
options in :class:`~repro.core.options.IteratedOptions` to control the
outer loop.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .nonlinear import iterated_solve
from .options import DistributedOptions, IteratedOptions, SolverOptions
from .padding import bucket_length, next_pow2, pad_record, slice_solution
from .registry import MethodSpec, get_method
from .sde import (
    LinearSDE,
    NonlinearSDE,
    grid_lqt_from_linear,
    om_cost_grid,
)
from .types import BucketInfo, PaddingReport, Solution

Model = Union[LinearSDE, NonlinearSDE]
Records = Sequence[Tuple[np.ndarray, np.ndarray]]


# ---------------------------------------------------------------------------
# Executable cache (absorbed from the old core/batching.py)
# ---------------------------------------------------------------------------


class ExecutableCache:
    """LRU cache of jitted solvers keyed by (model, mesh, method, options,
    problem layout).

    Models are frozen dataclasses holding arrays (unhashable), so the key
    uses ``id(model)``; a strong reference to the model (and mesh) is kept
    in the entry so the id cannot be recycled while cached.  ``maxsize``
    bounds retained executables/models: callers constructing a fresh model
    per request never hit (new id each time) and would otherwise grow the
    cache without bound -- reuse one model object to get executable reuse.

    Hit/miss/eviction counts are kept as plain ints (always, they cost
    nothing) and mirrored into the ``repro.obs`` registry counters
    ``cache.hits`` / ``cache.misses`` / ``cache.evictions`` while obs is
    enabled (aggregated across all cache instances -- the module default
    plus any private ``Estimator(cache=...)`` caches).
    """

    def __init__(self, maxsize: int = 128) -> None:
        self._entries: "collections.OrderedDict[tuple, tuple]" = (
            collections.OrderedDict())
        self._lock = threading.RLock()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_entry(self, model: Model, mesh, key_tail: tuple, build):
        """Fetch-or-build; returns ``(fn, fresh)`` where ``fresh`` marks a
        miss (``fn`` was just built and has not executed/compiled yet)."""
        key = (id(model), None if mesh is None else id(mesh)) + key_tail
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.hits += 1
                self._entries.move_to_end(key)
                obs.inc("cache.hits")
                return entry[0], False
            self.misses += 1
            obs.inc("cache.misses")
            fn = build()
            self._entries[key] = (fn, model, mesh)
            if len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.evictions += 1
                obs.inc("cache.evictions")
            return fn, True

    def get(self, model: Model, mesh, key_tail: tuple, build):
        return self.get_entry(model, mesh, key_tail, build)[0]

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)


_CACHE = ExecutableCache()


def cache_stats() -> Dict[str, int]:
    """Default executable-cache counters: one miss per compiled (layout,
    method, options) combination, hits for every reuse, evictions when
    ``maxsize`` forces an LRU drop.

    These are the same counts the obs registry exports as ``cache.*``
    (aggregated over every cache instance) -- ``repro.obs.snapshot()``
    additionally carries the ``cache.compile_seconds`` histogram recorded
    around fresh-executable first runs.  See docs/OBSERVABILITY.md.
    """
    return {"size": len(_CACHE), "hits": _CACHE.hits,
            "misses": _CACHE.misses, "evictions": _CACHE.evictions}


def clear_cache() -> None:
    _CACHE.clear()


# ---------------------------------------------------------------------------
# Problem
# ---------------------------------------------------------------------------


def _check_ny(model: Model, y, where: str = "") -> None:
    """Reject measurements whose trailing dimension does not match the
    model's ``ny`` -- a mismatched ``y`` would otherwise BROADCAST
    silently against ``H x`` in the measurement cost and produce garbage
    estimates instead of an error (skipped when ``R`` is time-varying
    and ``ny`` is not statically known)."""
    ny = model.ny
    if ny is not None and y.shape[-1] != ny:
        raise ValueError(
            f"{where}y has measurement dimension {y.shape[-1]} but the "
            f"model's R is {ny}x{ny} (ny={ny})")


def _check_mask(mask, shape) -> jnp.ndarray:
    mask = jnp.asarray(mask)
    if mask.shape != shape:
        raise ValueError(
            f"measurement_mask must have shape {shape}, got {mask.shape}")
    if jnp.issubdtype(mask.dtype, jnp.bool_) or jnp.issubdtype(
            mask.dtype, jnp.integer):
        mask = mask.astype(jnp.result_type(float))   # 0/1 masks are welcome
    elif not jnp.issubdtype(mask.dtype, jnp.floating):
        raise ValueError(
            f"measurement_mask must be a real 0/1 array (it scales R^-1), "
            f"got dtype {mask.dtype}")
    return mask


def _check_prior(model, prior, batch: Optional[int]):
    """Validate an information-form prior override ``(S0, v0)``.

    ``S0`` is the information matrix (``P0^{-1}``) and ``v0`` the
    information vector (``P0^{-1} m0``) at the first grid point --
    replacing the model's ``(m0, P0)`` boundary without any inversion.
    Shapes: shared ``(nx, nx)``/``(nx,)`` or, for stacked/ragged layouts,
    per-record ``(B, nx, nx)``/``(B, nx)`` (both components must agree).
    """
    if prior is None:
        return None
    try:
        S0, v0 = prior
    except (TypeError, ValueError):
        raise ValueError(
            "prior must be an information-form pair (S0, v0)") from None
    S0, v0 = jnp.asarray(S0), jnp.asarray(v0)
    nx = model.nx
    s_ok, v_ok = {(nx, nx)}, {(nx,)}
    if batch is not None:
        s_ok.add((batch, nx, nx))
        v_ok.add((batch, nx))
    if S0.shape not in s_ok or v0.shape not in v_ok:
        raise ValueError(
            f"prior (S0, v0) must have shapes {sorted(s_ok)} / "
            f"{sorted(v_ok)}, got {S0.shape} / {v0.shape}")
    if (S0.ndim == 3) != (v0.ndim == 2):
        raise ValueError(
            f"prior S0 and v0 must be both shared or both per-record, "
            f"got shapes {S0.shape} / {v0.shape}")
    return (S0, v0)


def _check_x_init(model, x_init, N: int, batch: Optional[int]):
    if x_init is None:
        return None
    if not isinstance(model, NonlinearSDE):
        raise ValueError(
            "x_init is only meaningful for NonlinearSDE problems (it warm-"
            "starts the iterated linearisation)")
    x_init = jnp.asarray(x_init)
    nx = model.nx
    shared = {(nx,), (N + 1, nx)}
    if batch is None:
        if x_init.shape not in shared:
            raise ValueError(
                f"x_init must be ({nx},) or ({N + 1}, {nx}), "
                f"got {x_init.shape}")
    else:
        batched = {(batch, nx), (batch, N + 1, nx)}
        if x_init.shape not in shared | batched:
            raise ValueError(
                f"x_init must be shared ({nx},)/({N + 1}, {nx}) or "
                f"per-record ({batch}, {nx})/({batch}, {N + 1}, {nx}), "
                f"got {x_init.shape}")
    return x_init


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """One estimation workload: model + data (+ optional mask/warm start).

    Build via :meth:`single`, :meth:`stacked` or :meth:`ragged` -- the
    constructors validate shapes/dtypes up front so errors surface at
    construction, not inside a trace.  ``kind`` records the layout; for
    ragged problems ``ts``/``y`` (and a per-record ``x_init``) are tuples
    of per-record arrays.
    """

    model: Model
    ts: Any
    y: Any
    measurement_mask: Optional[jnp.ndarray] = None
    x_init: Any = None
    prior: Any = None
    kind: str = "single"
    bucket_sizes: Optional[Tuple[int, ...]] = None
    pad_batch: bool = True

    # -- constructors -------------------------------------------------------

    @classmethod
    def single(cls, model: Model, ts, y, *, measurement_mask=None,
               x_init=None, prior=None) -> "Problem":
        """One record: ``ts`` ``(N+1,)``, ``y`` ``(N, ny)``.

        ``prior`` ``(S0, v0)``: information-form initial boundary
        (``P0^{-1}``, ``P0^{-1} m0``) replacing the model's ``(m0, P0)``
        -- fixed-lag window solves pass the forward-filter information at
        the window's left edge here (see docs/STREAMING.md)."""
        ts = jnp.asarray(ts)
        y = jnp.asarray(y)
        if y.ndim != 2 or y.shape[0] < 1:
            raise ValueError(f"y must be (N, ny) with N >= 1, got {y.shape}")
        N = y.shape[0]
        if ts.shape != (N + 1,):
            raise ValueError(f"ts must be (N+1,) = {(N + 1,)}, got {ts.shape}")
        _check_ny(model, y)
        if measurement_mask is not None:
            measurement_mask = _check_mask(measurement_mask, (N,))
        x_init = _check_x_init(model, x_init, N, None)
        prior = _check_prior(model, prior, None)
        return cls(model, ts, y, measurement_mask, x_init, prior,
                   kind="single")

    @classmethod
    def stacked(cls, model: Model, ts, ys, *, measurement_mask=None,
                x_init=None, prior=None) -> "Problem":
        """Stacked records ``ys`` ``(B, N, ny)`` sharing the interval
        count; ``ts`` shared ``(N+1,)`` or per-record ``(B, N+1)``.

        ``x_init`` (nonlinear models): shared ``(nx,)`` / ``(N+1, nx)``
        or per-record ``(B, nx)`` / ``(B, N+1, nx)``.  If ``B == N+1``
        makes a rank-2 shape ambiguous, the per-record reading wins --
        tile to ``(B, N+1, nx)`` to force a shared trajectory.

        ``prior`` ``(S0, v0)``: shared ``(nx, nx)``/``(nx,)`` or
        per-record ``(B, nx, nx)``/``(B, nx)`` information-form initial
        boundaries (see :meth:`single`)."""
        ys = jnp.asarray(ys)
        if ys.ndim != 3:
            raise ValueError(f"ys must be (B, N, ny), got shape {ys.shape}")
        ts = jnp.asarray(ts)
        B, N = ys.shape[0], ys.shape[1]
        if ts.shape[-1] != N + 1:
            raise ValueError(
                f"ts has {ts.shape[-1]} points but ys has {N} intervals "
                f"(need N+1 = {N + 1})")
        if ts.ndim == 2 and ts.shape[0] != B:
            raise ValueError(f"ts batch {ts.shape[0]} != ys batch {B}")
        if ts.ndim not in (1, 2):
            raise ValueError(f"ts must be (N+1,) or (B, N+1), got {ts.shape}")
        _check_ny(model, ys)
        if measurement_mask is not None:
            measurement_mask = _check_mask(measurement_mask, (B, N))
        x_init = _check_x_init(model, x_init, N, B)
        prior = _check_prior(model, prior, B)
        return cls(model, ts, ys, measurement_mask, x_init, prior,
                   kind="stacked")

    @classmethod
    def ragged(cls, model: Model, records: Records, *, x_init=None,
               prior=None, bucket_sizes: Optional[Sequence[int]] = None,
               pad_batch: bool = True) -> "Problem":
        """Records of unequal length: ``records`` is a sequence of
        ``(ts_i, y_i)`` pairs with ``ts_i`` ``(N_i+1,)``, ``y_i``
        ``(N_i, ny)``.  ``x_init`` may be one shared ``(nx,)`` point or a
        sequence of per-record ``(nx,)`` points.  Solved by pad-and-bucket
        (see :mod:`repro.core.padding`); the returned solutions carry a
        :class:`~repro.core.types.PaddingReport`.
        """
        records = tuple(records)
        if not records:
            raise ValueError("records must be non-empty")
        ts_all, y_all = [], []
        for i, (ts_i, y_i) in enumerate(records):
            ts_i = np.asarray(ts_i)
            y_i = np.asarray(y_i)
            if y_i.ndim != 2 or y_i.shape[0] < 1:
                raise ValueError(
                    f"record {i}: y must be (N, ny) with N >= 1, "
                    f"got {y_i.shape}")
            if ts_i.shape != (y_i.shape[0] + 1,):
                raise ValueError(
                    f"record {i}: ts must be (N+1,) = "
                    f"{(y_i.shape[0] + 1,)}, got {ts_i.shape}")
            _check_ny(model, y_i, where=f"record {i}: ")
            ts_all.append(ts_i)
            y_all.append(y_i)
        if x_init is not None:
            if not isinstance(model, NonlinearSDE):
                raise ValueError(
                    "x_init is only meaningful for NonlinearSDE problems")
            x_init = np.asarray(x_init)
            nx = model.nx
            if x_init.shape not in {(nx,), (len(records), nx)}:
                raise ValueError(
                    f"ragged x_init must be ({nx},) shared or "
                    f"({len(records)}, {nx}) per-record points, "
                    f"got {x_init.shape}")
        prior = _check_prior(model, prior, len(records))
        return cls(model, tuple(ts_all), tuple(y_all), None, x_init, prior,
                   kind="ragged",
                   bucket_sizes=None if bucket_sizes is None
                   else tuple(bucket_sizes),
                   pad_batch=pad_batch)

    # -- layout helpers -----------------------------------------------------

    @property
    def num_records(self) -> int:
        if self.kind == "single":
            return 1
        if self.kind == "stacked":
            return self.y.shape[0]
        return len(self.y)

    @property
    def lengths(self) -> Tuple[int, ...]:
        """Interval count per record."""
        if self.kind == "single":
            return (self.y.shape[0],)
        if self.kind == "stacked":
            return (self.y.shape[1],) * self.y.shape[0]
        return tuple(y_i.shape[0] for y_i in self.y)


# ---------------------------------------------------------------------------
# Estimator
# ---------------------------------------------------------------------------


def _solve_arrays(model: Model, spec: MethodSpec, options, ts, y, mask,
                  x_init, prior=None, diagnostics: bool = True) -> Solution:
    """Solve ONE record; the traced core every executable is built from.

    ``diagnostics=False`` skips the Onsager-Machlup cost evaluation (a
    pinv/eval pass over the grid per solve -- small next to the solve, but
    pure overhead for callers that never read ``Solution.cost``).
    """
    if isinstance(model, NonlinearSDE):
        inner = options.inner
        sol, trace, steps = iterated_solve(
            model, ts, y, lambda grid: spec.solver(grid, inner),
            iterations=options.iterations,
            divergence_correction=options.divergence_correction,
            x_init=x_init, measurement_mask=mask, prior=prior,
            track_costs=diagnostics,
            linearization=options.linearization)
        if not diagnostics:
            return Solution(x=sol.x, S=sol.S, v=sol.v, cov=sol.cov)
        return Solution(x=sol.x, S=sol.S, v=sol.v, cov=sol.cov,
                        cost=trace[-1], cost_trace=trace, step_norms=steps)
    grid = grid_lqt_from_linear(model, ts, y, measurement_mask=mask,
                                prior=prior)
    sol = spec.solver(grid, options)
    return Solution(x=sol.x, S=sol.S, v=sol.v, cov=sol.cov,
                    cost=om_cost_grid(grid, sol.x) if diagnostics else None)


def legacy_options(model: Model, method: str, *, nsub=None, mode=None,
                   iterations=None, divergence_correction=None):
    """Map the old kwarg soup onto the method's options dataclass
    (deprecation-shim support; fields a method does not declare are
    dropped, mirroring how the old dispatch ignored them)."""
    spec = get_method(method)
    inner = spec.options_cls.from_legacy(nsub=nsub, mode=mode)
    if isinstance(model, NonlinearSDE):
        outer = {k: v for k, v in
                 dict(iterations=iterations,
                      divergence_correction=divergence_correction).items()
                 if v is not None}
        return IteratedOptions(inner=inner, **outer)
    return inner


class Estimator:
    """Compiled MAP estimation for one model + method + options.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`; problems
        passed to :meth:`solve` must be built with this model object (the
        executable cache is anchored on it).
      method: registered method name (see
        :func:`repro.core.registry.method_names`).  Backends are fully
        interchangeable here -- e.g. ``"parallel_kernel"`` (the Pallas
        lane-major scan, ``docs/KERNELS.md``) runs through the same
        executable cache, vmap/shard_map batching and AOT ``lower`` path
        as the jnp methods.  Iterated nonlinear methods
        (``"sigma_point"``) are NOT grid solvers: they require a
        ``NonlinearSDE`` and run the iterated linearisation loop around
        the linear method named by their options' ``inner_method``.
      options: instance of the method's options class
        (:class:`~repro.core.options.SolverOptions` subclass); for
        nonlinear models either that (outer loop defaults) or an
        :class:`~repro.core.options.IteratedOptions` wrapping it.  ``None``
        means all defaults.
      mesh: optional ``jax.sharding.Mesh`` OR
        :class:`repro.distributed.MeshSpec` (the one mesh entry point --
        normalised via :func:`repro.distributed.as_mesh`).  Stacked
        batches are sharded over ``mesh.shape[batch_axis]`` devices;
        ``method="distributed"`` additionally shards the time axis over
        the mesh axis named by its options (an ambient
        :meth:`MeshSpec.activate` / ``mesh_context`` mesh is picked up
        when this argument is ``None``).  A mesh/device fingerprint is
        part of the executable-cache key, so an executable compiled under
        one mesh is never replayed under another.
      diagnostics: compute ``Solution.cost`` / ``cost_trace`` (default).
        ``False`` skips the Onsager-Machlup evaluations -- use for hot
        serving paths that never read them.
      cache: optional private :class:`ExecutableCache` (default: the
        module-level cache shared by all estimators).
    """

    def __init__(self, model: Model, *, method: str = "parallel_rts",
                 options=None, mesh=None, batch_axis: str = "data",
                 diagnostics: bool = True,
                 cache: Optional[ExecutableCache] = None):
        from repro.distributed.sharding import as_mesh

        self._spec = get_method(method)
        self.model = model
        self.method = method
        self.options = self._resolve_options(options)
        # The spec that actually solves each (linearised) grid problem:
        # iterated nonlinear methods (spec.nonlinear, e.g. "sigma_point")
        # delegate to their options' inner_method; every other method IS
        # the grid solver.
        self._grid_spec = (get_method(self.options.inner_method)
                           if self._spec.nonlinear else self._spec)
        self.mesh = as_mesh(mesh)
        self.batch_axis = batch_axis
        self.diagnostics = diagnostics
        self._cache = _CACHE if cache is None else cache
        self._distributed = issubclass(self._grid_spec.options_cls,
                                       DistributedOptions)

    def _resolve_options(self, options):
        cls = self._spec.options_cls
        if self._spec.nonlinear:
            # Iterated nonlinear method (e.g. "sigma_point"): the options
            # ARE the outer-loop options; the grid solver is named by
            # options.inner_method and its options ride in options.inner.
            if not isinstance(self.model, NonlinearSDE):
                raise TypeError(
                    f"method {self.method!r} is an iterated nonlinear "
                    f"method and needs a NonlinearSDE model, got "
                    f"{type(self.model).__name__}")
            if options is None:
                options = cls()
            elif isinstance(options, SolverOptions):
                options = cls(inner=options)
            elif not isinstance(options, cls):
                raise TypeError(
                    f"options for method {self.method!r} must be "
                    f"{cls.__name__} (or a bare inner-method SolverOptions),"
                    f" got {type(options).__name__}")
            inner_spec = get_method(options.inner_method)
            if inner_spec.nonlinear:
                raise ValueError(
                    f"inner_method {options.inner_method!r} is itself an "
                    f"iterated nonlinear method; it must name a linear grid "
                    f"solver (e.g. 'parallel_rts', 'sequential_rts')")
            inner = (options.inner if options.inner is not None
                     else inner_spec.options_cls())
            if not isinstance(inner, inner_spec.options_cls):
                raise TypeError(
                    f"{cls.__name__}.inner for inner_method "
                    f"{options.inner_method!r} must be "
                    f"{inner_spec.options_cls.__name__}, got "
                    f"{type(inner).__name__}")
            return options.replace(inner=inner)
        if isinstance(self.model, NonlinearSDE):
            if options is None:
                options = IteratedOptions()
            elif isinstance(options, cls):
                options = IteratedOptions(inner=options)
            elif not isinstance(options, IteratedOptions):
                raise TypeError(
                    f"options for nonlinear method {self.method!r} must be "
                    f"{cls.__name__} or IteratedOptions, got "
                    f"{type(options).__name__}")
            if type(options) is not IteratedOptions:
                raise TypeError(
                    f"{type(options).__name__} belongs to an iterated "
                    f"nonlinear method, not method={self.method!r}; use "
                    f"the method it was registered with (e.g. "
                    f"method='sigma_point') or plain IteratedOptions")
            inner = options.inner if options.inner is not None else cls()
            if not isinstance(inner, cls):
                raise TypeError(
                    f"IteratedOptions.inner for method {self.method!r} must "
                    f"be {cls.__name__}, got {type(inner).__name__}")
            return options.replace(inner=inner)
        if isinstance(options, IteratedOptions):
            raise TypeError(
                "IteratedOptions is for NonlinearSDE models; linear models "
                f"take {cls.__name__}")
        if options is None:
            options = cls()
        if not isinstance(options, cls):
            raise TypeError(
                f"options for method {self.method!r} must be "
                f"{cls.__name__}, got {type(options).__name__}")
        return options

    @property
    def block_size(self) -> int:
        """Grid-length multiple required by the method (``nsub`` for
        parallel methods, 1 otherwise) -- the bucketing unit."""
        o = self.options
        if isinstance(o, IteratedOptions):
            o = o.inner
        return getattr(o, "nsub", 1)

    # -- mesh plumbing ------------------------------------------------------

    def _method_options(self):
        """The method-level options (unwrapping ``IteratedOptions``)."""
        o = self.options
        return o.inner if isinstance(o, IteratedOptions) else o

    def _resolved_mesh(self):
        """The mesh THIS solve will actually run under.

        Non-distributed methods use ``self.mesh`` as-is.  The distributed
        method resolves exactly like its solver will at trace time
        (explicit mesh, else ambient context, else default time-only
        mesh; ``None`` = single-device fallback), so the executable-cache
        fingerprint and the traced collectives always agree.
        """
        if not self._distributed:
            return self.mesh
        from repro.distributed.sharding import resolve_time_mesh

        o = self._method_options()
        return resolve_time_mesh(o.time_axis,
                                 devices_per_time=o.devices_per_time,
                                 mesh=self.mesh)

    def _batch_spmd_axis(self, mesh) -> Optional[str]:
        """The mesh axis a distributed stacked batch shards over: the
        first of ``options.batch_axes`` present on the mesh (so the same
        options work on time-only and 2-D meshes)."""
        if mesh is None:
            return None
        o = self._method_options()
        for a in o.batch_axes:
            if a in mesh.axis_names and a != o.time_axis:
                return a
        return None

    def _batch_shard_size(self, mesh) -> int:
        """Devices the stacked batch axis spreads over (1 = unsharded)."""
        if mesh is None:
            return 1
        if self._distributed:
            ax = self._batch_spmd_axis(mesh)
            return mesh.shape[ax] if ax is not None else 1
        if self.batch_axis in mesh.axis_names:
            return mesh.shape[self.batch_axis]
        return 1

    def _mesh_scope(self):
        """Context activating ``self.mesh`` around traced calls, so the
        distributed solver resolves the SAME mesh the cache key was
        fingerprinted with (jit traces lazily, inside the first call)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distributed.sharding import mesh_context

        return mesh_context(self.mesh, batch_axes=(self.batch_axis,))

    # -- executable construction -------------------------------------------

    def _check_model(self, problem: Problem) -> None:
        if problem.model is not self.model:
            raise ValueError(
                "problem.model is not this Estimator's model object; build "
                "the Problem with the same model instance (executables are "
                "cached per model object)")

    def _prepare(self, problem: Problem):
        """Fetch/compile the executable for this problem's layout; returns
        ``(jitted_fn, args, fresh)`` -- ``fresh`` marks a cache miss (the
        executable compiles on its first run)."""
        self._check_model(problem)
        from repro.distributed.sharding import mesh_fingerprint

        ts, y = problem.ts, problem.y
        mask, x_init = problem.measurement_mask, problem.x_init
        stacked = problem.kind == "stacked"
        resolved = self._resolved_mesh()
        if stacked:
            axis = self._batch_shard_size(resolved)
            if axis > 1 and y.shape[0] % axis:
                raise ValueError(
                    f"batch {y.shape[0]} not divisible by mesh batch axis "
                    f"size {axis}")

        args: List[Any] = [ts, y]
        axes: List[Optional[int]] = [0 if (stacked and ts.ndim == 2) else None,
                                     0 if stacked else None]
        if mask is not None:
            args.append(mask)
            axes.append(0 if stacked else None)
        if x_init is not None:
            args.append(x_init)
            if not stacked:
                axes.append(None)
            else:
                # (nx,) / (N+1, nx) are shared, (B, nx) / (B, N+1, nx)
                # per-record; in the ambiguous B == N+1 rank-2 case the
                # per-record reading wins (tile to (B, N+1, nx) to force a
                # shared trajectory).
                B = y.shape[0]
                shared = x_init.ndim == 1 or (
                    x_init.ndim == 2 and x_init.shape[0] != B)
                axes.append(None if shared else 0)
        prior = problem.prior
        if prior is not None:
            per_rec = stacked and prior[0].ndim == 3
            args.extend(prior)
            axes.extend([0 if per_rec else None] * 2)

        has_mask, has_xinit = mask is not None, x_init is not None
        has_prior = prior is not None
        # mesh_fingerprint of the RESOLVED mesh: an executable traced
        # under one mesh (its collectives bake in axis names, shard
        # counts and device ids) is never replayed under another, even
        # when the Estimator itself holds mesh=None and the mesh arrives
        # ambiently.
        key_tail = (
            self.method, self.options, problem.kind, self.batch_axis,
            mesh_fingerprint(resolved),
            has_mask, has_xinit, has_prior, self.diagnostics,
            tuple((a.shape, str(a.dtype)) for a in args),
            tuple(axes))
        model, spec, options = self.model, self._grid_spec, self.options
        spmd_axis = self._batch_spmd_axis(resolved) if (
            stacked and self._distributed) else None

        def build():
            def solve_one(*call_args):
                it = iter(call_args)
                t, yy = next(it), next(it)
                m = next(it) if has_mask else None
                xi = next(it) if has_xinit else None
                pr = (next(it), next(it)) if has_prior else None
                return _solve_arrays(model, spec, options, t, yy, m, xi,
                                     prior=pr,
                                     diagnostics=self.diagnostics)

            fn = solve_one
            if stacked:
                if self._distributed:
                    # vmap composes with the solver's inner shard_map;
                    # spmd_axis_name lands the batch dim on the mesh's
                    # batch axis for 2-D (time x batch) layouts.  (A
                    # shard_map wrapper would nest shard_maps, which jax
                    # does not support.)
                    if spmd_axis is not None and resolved.shape[
                            spmd_axis] > 1:
                        fn = jax.vmap(fn, in_axes=tuple(axes),
                                      spmd_axis_name=spmd_axis)
                    else:
                        fn = jax.vmap(fn, in_axes=tuple(axes))
                else:
                    fn = jax.vmap(fn, in_axes=tuple(axes))
                    if (self.mesh is not None
                            and self.batch_axis in self.mesh.axis_names):
                        from repro.distributed.sharding import (
                            shard_over_batch)
                        fn = shard_over_batch(
                            fn, self.mesh, self.batch_axis,
                            tuple(ax == 0 for ax in axes))
            return jax.jit(fn)

        fn, fresh = self._cache.get_entry(model, self.mesh, key_tail, build)
        return fn, tuple(args), fresh

    # -- public surface -----------------------------------------------------

    def solve(self, problem: Problem):
        """Solve a :class:`Problem`.

        Returns a :class:`~repro.core.types.Solution` (single/stacked
        layouts; stacked fields carry a leading batch axis) or a list of
        per-record ``Solution``\\ s in submission order (ragged layout,
        each carrying the shared
        :class:`~repro.core.types.PaddingReport`).

        While ``repro.obs`` is enabled (and ``diagnostics`` is on -- the
        hot-serving opt-out also silences instrumentation) the solve is
        measured: phase spans ``estimator.solve.{prepare,compile,execute,
        host_transfer}``, the ``cache.compile_seconds`` histogram for
        fresh executables, and nonlinear iteration metrics.  The measured
        path blocks on the result (spans time real work, not dispatch);
        outputs are bit-exact either way.
        """
        if problem.kind == "ragged":
            return self._solve_ragged(problem)
        if not (self.diagnostics and obs.enabled()):
            # hot path: no obs objects touched, fully async dispatch
            with self._mesh_scope():
                fn, args, _ = self._prepare(problem)
                return fn(*args)
        with obs.trace_span("estimator.solve"):
            with obs.trace_span("estimator.solve.prepare"):
                fn, args, fresh = self._prepare(problem)
            phase = ("estimator.solve.compile" if fresh
                     else "estimator.solve.execute")
            t0 = time.perf_counter()
            with obs.trace_span(phase, xla=True), self._mesh_scope():
                out = fn(*args)
                jax.block_until_ready(out)
            if fresh:
                obs.record("cache.compile_seconds",
                           time.perf_counter() - t0)
            with obs.trace_span("estimator.solve.host_transfer"):
                self._record_solution_metrics(out)
        return out

    def _record_solution_metrics(self, sol: Solution) -> None:
        """Host-side readout of per-solve diagnostics into the registry
        (concrete device arrays only -- never called from traced code)."""
        obs.inc("estimator.solves")
        if isinstance(self.options, IteratedOptions):
            lin = self.options.linearization
            obs.inc(f"linearize.{lin.obs_name}.solves")
            obs.set_gauge("linearize.sigma_points",
                          lin.num_points(self.model.nx))
        if sol.cost is not None:
            obs.record("estimator.final_cost", np.mean(np.asarray(sol.cost)))
        if sol.cost_trace is not None:
            trace = np.asarray(sol.cost_trace)
            obs.set_gauge("nonlinear.iterations", trace.shape[-1])
            obs.record("nonlinear.cost_decrease",
                       float(np.mean(trace[..., 0] - trace[..., -1])))
        if sol.step_norms is not None:
            steps = np.asarray(sol.step_norms)
            obs.record("nonlinear.final_step_norm",
                       float(np.mean(steps[..., -1])))

    def lower(self, problem: Problem) -> "jax.stages.Lowered":
        """Ahead-of-time path: the ``jax.stages.Lowered`` for this
        problem's layout (``.compile()`` it, then call with the problem's
        arrays).  Ragged problems compose several stacked executables and
        cannot be lowered as one program -- lower per-bucket stacked
        problems instead."""
        if problem.kind == "ragged":
            raise ValueError(
                "lower() supports single/stacked problems; a ragged solve "
                "composes one executable per bucket")
        with obs.trace_span("estimator.lower"), self._mesh_scope():
            fn, args, _ = self._prepare(problem)
            return fn.lower(*args)

    # -- ragged pad-and-bucket ---------------------------------------------

    def _solve_ragged(self, problem: Problem) -> List[Solution]:
        self._check_model(problem)
        nsub = self.block_size
        lengths = problem.lengths
        buckets: Dict[int, List[int]] = {}
        for i, N_i in enumerate(lengths):
            n_pad = bucket_length(N_i, nsub, problem.bucket_sizes)
            buckets.setdefault(n_pad, []).append(i)

        x_init = problem.x_init
        per_record_xi = x_init is not None and x_init.ndim == 2
        prior = problem.prior
        per_record_prior = prior is not None and prior[0].ndim == 3

        out: List[Optional[Solution]] = [None] * len(lengths)
        infos: List[BucketInfo] = []
        for n_pad, idxs in sorted(buckets.items()):
            padded = [pad_record(problem.ts[i], problem.y[i], n_pad)
                      for i in idxs]
            B = len(padded)
            B_pad = next_pow2(B) if problem.pad_batch else B
            axis = self._batch_shard_size(self._resolved_mesh())
            if axis > 1:
                B_pad = -(-B_pad // axis) * axis
            rows = padded + [padded[0]] * (B_pad - B)   # recycle row 0
            ts_b = jnp.asarray(np.stack([r[0] for r in rows]))
            ys_b = jnp.asarray(np.stack([r[1] for r in rows]))
            mask_b = jnp.asarray(np.stack([r[2] for r in rows]))
            xi_b = None
            if per_record_xi:
                xi_rows = [x_init[i] for i in idxs]
                xi_b = jnp.asarray(np.stack(
                    xi_rows + [xi_rows[0]] * (B_pad - B)))
            elif x_init is not None:
                xi_b = jnp.asarray(x_init)
            pr_b = prior
            if per_record_prior:
                recycle = [idxs[0]] * (B_pad - B)
                pr_b = (jnp.stack([prior[0][i] for i in idxs + recycle]),
                        jnp.stack([prior[1][i] for i in idxs + recycle]))
            sub = Problem.stacked(self.model, ts_b, ys_b,
                                  measurement_mask=mask_b, x_init=xi_b,
                                  prior=pr_b)
            sol = self.solve(sub)
            infos.append(BucketInfo(n_pad=n_pad, records=B, batch=B_pad))
            for row, i in enumerate(idxs):
                out[i] = slice_solution(sol, row, lengths[i])

        report = PaddingReport(lengths=tuple(lengths), buckets=tuple(infos))
        if self.diagnostics and obs.enabled():
            obs.inc("padding.records", report.records)
            obs.inc("padding.real_intervals", report.real_intervals)
            obs.inc("padding.solved_intervals", report.solved_intervals)
            obs.set_gauge("padding.interval_utilisation",
                          report.interval_utilisation)
            obs.set_gauge("padding.row_utilisation", report.row_utilisation)
            obs.set_gauge("padding.waste", 1.0 - report.interval_utilisation)
        return [dataclasses.replace(s, padding=report) for s in out]
