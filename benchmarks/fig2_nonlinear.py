"""Paper Fig. 2: runtime of the iterated (5x) MAP estimator on the
coordinated-turn model (eqs. 55-58), sequential vs parallel RTS backend.

The paper excludes the two-filter smoother here (more expensive, section
5.2); we do the same but keep it one flag away.  Span column as in fig1.
"""
from __future__ import annotations

import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp


def run(T_list=(64, 128, 256, 512), nsub=10, mode="euler", repeats=5,
        iterations=5, include_tf=False):
    from repro.configs.coordinated_turn import CoordinatedTurnConfig
    from repro.core import (
        Estimator, IteratedOptions, Problem, get_method, simulate_nonlinear,
        time_grid,
    )

    ccfg = CoordinatedTurnConfig(iterations=iterations)
    model = ccfg.model()
    rows = []
    methods = ["sequential_rts", "parallel_rts"]
    if include_tf:
        methods.append("parallel_two_filter")
    for T in T_list:
        N = T * nsub
        ts = time_grid(ccfg.t0, ccfg.tf, N, dtype=jnp.float32)
        _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(1))
        for method in methods:
            inner = get_method(method).options_cls.from_legacy(
                nsub=nsub, mode=mode)
            est = Estimator(model, method=method,
                            options=IteratedOptions(iterations=iterations,
                                                    inner=inner))
            compiled = est.lower(
                Problem.single(model, ts, y)).compile()    # AOT executable
            fn = lambda yy: compiled(ts, yy).x
            fn(y).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(repeats):
                fn(y).block_until_ready()
            dt = (time.perf_counter() - t0) / repeats
            span = iterations * (
                2 * N if method.startswith("seq")
                else 4 * math.ceil(math.log2(T + 1)) + 2 * nsub)
            rows.append({
                "name": f"fig2/{method}/T{T}",
                "us_per_call": dt * 1e6,
                "derived": f"span={span}",
            })
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
