"""Continuous-time MAP trajectory estimation, parallel-in-time.

Implements Razavi, Garcia-Fernandez & Sarkka (2025), "Temporal
parallelisation of continuous-time maximum-a-posteriori trajectory
estimation": parallel Kalman-Bucy filtering, parallel continuous-time RTS
and two-filter smoothing, and iterated linearisation for nonlinear models,
all built on associative scans.
"""
from .api import map_estimate, METHODS
from .batching import (
    bucket_length,
    cache_stats,
    clear_cache,
    map_estimate_batched,
    map_estimate_ragged,
    pad_record,
    slice_solution,
)
from .combine import (
    affine_combine,
    apply_element_to_value,
    elem_min_initial,
    lqt_combine,
    value_as_element,
)
from .nonlinear import iterated_map
from .oracle import qp_map_estimate, qp_map_from_grid
from .registry import get_solver, method_names, register_method
from .parallel import parallel_backward, parallel_rts, parallel_two_filter
from .pscan import distributed_scan, prefix_scan, suffix_scan
from .sde import (
    LinearSDE,
    NonlinearSDE,
    build_grid_lqt,
    grid_lqt_from_linear,
    grid_lqt_from_nonlinear,
    om_cost_linear,
    om_cost_nonlinear,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)
from .sequential import (
    sequential_backward,
    sequential_rts,
    sequential_two_filter,
)
from .types import (
    AffineElement,
    GridLQT,
    LQTElement,
    MAPSolution,
    ValueFn,
)

__all__ = [
    "AffineElement", "GridLQT", "LQTElement", "MAPSolution", "ValueFn",
    "LinearSDE", "NonlinearSDE", "METHODS",
    "map_estimate", "iterated_map",
    "map_estimate_batched", "map_estimate_ragged",
    "bucket_length", "pad_record", "slice_solution",
    "cache_stats", "clear_cache",
    "get_solver", "method_names", "register_method",
    "parallel_backward", "parallel_rts", "parallel_two_filter",
    "sequential_backward", "sequential_rts", "sequential_two_filter",
    "prefix_scan", "suffix_scan", "distributed_scan",
    "lqt_combine", "affine_combine", "apply_element_to_value",
    "value_as_element", "elem_min_initial",
    "build_grid_lqt", "grid_lqt_from_linear", "grid_lqt_from_nonlinear",
    "simulate_linear", "simulate_nonlinear", "time_grid",
    "om_cost_linear", "om_cost_nonlinear",
    "qp_map_estimate", "qp_map_from_grid",
]
