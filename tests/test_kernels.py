"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.elements import identity_element
from repro.core.types import LQTElement
from repro.kernels.flash_attention import attention, attention_trainable, mha_ref
from repro.kernels.lqt_combine import (
    kernel_prefix_scan,
    kernel_suffix_scan,
    lqt_combine_batched,
    lqt_combine_ref,
    lqt_scan_ref,
    scan_combine_fn,
)
from repro.kernels.lqt_combine.ops import _from_lanes, _pad_lanes, _to_lanes
from repro.kernels.ssd import ssd, ssd_ref, ssd_trainable

pytestmark = pytest.mark.kernel_interpret


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# lqt_combine
# ---------------------------------------------------------------------------

def _rand_elems(rng, B, nx, dtype):
    def psd():
        A = rng.standard_normal((B, nx, nx))
        return jnp.asarray(np.einsum("bij,bkj->bik", A, A) / nx
                           + 0.1 * np.eye(nx), dtype)

    return LQTElement(
        jnp.asarray(rng.standard_normal((B, nx, nx)) * 0.6, dtype),
        jnp.asarray(rng.standard_normal((B, nx)), dtype),
        psd(),
        jnp.asarray(rng.standard_normal((B, nx)), dtype),
        psd())


@pytest.mark.parametrize("nx", [2, 4, 5, 8])
@pytest.mark.parametrize("B,dtype", [
    (8, jnp.float32), (64, jnp.float32), (130, jnp.float64),
])
def test_lqt_combine_kernel_matches_ref(nx, B, dtype):
    rng = np.random.default_rng(nx * 1000 + B)
    e1 = _rand_elems(rng, B, nx, dtype)
    e2 = _rand_elems(rng, B, nx, dtype)
    got = lqt_combine_batched(e1, e2, interpret=True)
    want = lqt_combine_ref(*e1, *e2)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float64),
                                   np.asarray(w, np.float64),
                                   rtol=5e-5, atol=5e-5)


def test_kernel_backed_scan_matches_core_scan():
    """the kernel combine drops into pscan and reproduces the filter scan."""
    from repro.core import prefix_scan, lqt_combine as core_combine
    rng = np.random.default_rng(0)
    elems = _rand_elems(rng, 32, 4, jnp.float64)
    want = prefix_scan(core_combine, elems)
    got = prefix_scan(scan_combine_fn(interpret=True, block_b=8), elems)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-8, atol=1e-8)


# ---------------------------------------------------------------------------
# lqt_combine: lane-major layout plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,nx", [(1, 2), (7, 4), (32, 5)])
def test_to_from_lanes_round_trip_identity(B, nx):
    rng = np.random.default_rng(B * 10 + nx)
    e = _rand_elems(rng, B, nx, jnp.float64)
    back = _from_lanes(_to_lanes(e))
    for g, w in zip(back, e):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_pad_lanes_zero_pad_is_noop():
    rng = np.random.default_rng(0)
    ops = _to_lanes(_rand_elems(rng, 12, 3, jnp.float64))
    out = _pad_lanes(ops, 0)
    assert out is ops or all(a is b for a, b in zip(out, ops))
    padded = _pad_lanes(ops, 4)
    for a, b in zip(padded, ops):
        assert a.shape[-1] == b.shape[-1] + 4
        np.testing.assert_array_equal(np.asarray(a[..., :12]), np.asarray(b))
        assert not np.any(np.asarray(a[..., 12:]))


def _append_identities(e: LQTElement, k: int) -> LQTElement:
    eid = identity_element(e.nx, e.A.dtype)
    tail = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (k,) + a.shape), eid)
    return jax.tree_util.tree_map(
        lambda x, t: jnp.concatenate([x, t], axis=0), e, tail)


def test_identity_padded_tail_is_scan_identity():
    """Appending identity elements to the scan tail leaves every original
    prefix-scan entry unchanged (the padding contract of the kernel scan
    when a grid is bucketed up to a longer length)."""
    rng = np.random.default_rng(21)
    e = _rand_elems(rng, 7, 4, jnp.float64)         # non-pow2 scan length
    want = kernel_prefix_scan(e, interpret=True, block_b=8)
    padded = kernel_prefix_scan(_append_identities(e, 3), interpret=True,
                                block_b=8)
    for g, w in zip(jax.tree_util.tree_map(lambda a: a[:7], padded), want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-10, atol=1e-10)
    # ... and on the suffix side, identities PREPENDED are inert
    want_s = kernel_suffix_scan(e, interpret=True, block_b=8)
    eid = identity_element(4, e.A.dtype)
    head = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (3,) + a.shape), eid)
    pre = jax.tree_util.tree_map(
        lambda h, x: jnp.concatenate([h, x], axis=0), head, e)
    got_s = kernel_suffix_scan(pre, interpret=True, block_b=8)
    for g, w in zip(jax.tree_util.tree_map(lambda a: a[3:], got_s), want_s):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-10, atol=1e-10)


# ---------------------------------------------------------------------------
# lqt_combine: whole-scan kernel path vs the jnp scan oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T", [1, 2, 5, 13])
@pytest.mark.parametrize("reverse", [False, True])
def test_kernel_scan_matches_scan_ref(T, reverse):
    """One layout round-trip, multi-level lane-major scan == the core
    associative scan, for pow2 and non-pow2 scan lengths both ways."""
    rng = np.random.default_rng(100 + T)
    e = _rand_elems(rng, T, 4, jnp.float64)
    fn = kernel_suffix_scan if reverse else kernel_prefix_scan
    got = fn(e, interpret=True, block_b=8)
    want = lqt_scan_ref(e, reverse=reverse)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-9, atol=1e-9)


def test_kernel_scan_precision_cast_round_trips_dtype():
    rng = np.random.default_rng(3)
    e = _rand_elems(rng, 9, 3, jnp.float64)
    got = kernel_prefix_scan(e, interpret=True, block_b=8,
                             precision="float32")
    want = lqt_scan_ref(e)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype == jnp.float64    # cast back after scan
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # b, L, H, P, G, S, chunk
    (2, 64, 4, 16, 1, 8, 16),
    (1, 48, 6, 32, 2, 16, 16),
    (2, 33, 2, 8, 1, 4, 8),       # unaligned L -> padding path
    (1, 128, 2, 64, 1, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_ref(shape, dtype):
    b, L, H, P, G, S, chunk = shape
    rng = np.random.default_rng(L + H)
    x = jnp.asarray(rng.standard_normal((b, L, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, L, H)), dtype)
    A = jnp.asarray(-rng.uniform(0.2, 1.5, (H,)), dtype)
    B = jnp.asarray(rng.standard_normal((b, L, G, S)), dtype)
    C = jnp.asarray(rng.standard_normal((b, L, G, S)), dtype)
    D = jnp.asarray(rng.standard_normal((H,)), dtype)
    got = ssd(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    if dtype == jnp.bfloat16:
        # bf16: kernel accumulates f32 and rounds once, the bf16 ref rounds
        # per step -- judge both against the f32 oracle with an absolute
        # tolerance scaled to bf16 resolution at the output magnitude.
        f32 = jnp.float32
        want = ssd_ref(x.astype(f32), dt.astype(f32), A.astype(f32),
                       B.astype(f32), C.astype(f32), D.astype(f32))
        scale = float(jnp.abs(want).max())
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=0.04 * scale)
    else:
        want = ssd_ref(x, dt, A, B, C, D)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))


def test_ssd_trainable_grads_finite():
    rng = np.random.default_rng(1)
    b, L, H, P, G, S = 1, 32, 2, 8, 1, 4
    x = jnp.asarray(rng.standard_normal((b, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.2, 1.5, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, L, G, S)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, L, G, S)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((H,)), jnp.float32)

    def loss(*args):
        return jnp.sum(ssd_trainable(*args, 16, True) ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3, 4, 5))(x, dt, A, B, C, D)
    for g in grads:
        assert bool(jnp.isfinite(g).all())


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    # B, Hq, Hkv, Lq, Lk, D, causal, window, bq, bk
    (2, 4, 2, 64, 64, 16, True, None, 16, 16),
    (1, 6, 2, 32, 32, 32, True, 24, 16, 16),
    (2, 4, 4, 16, 64, 16, True, None, 16, 16),    # decode: Lq < Lk
    (1, 2, 1, 64, 64, 8, False, None, 32, 16),
    (1, 8, 1, 128, 128, 16, True, 32, 32, 32),    # MQA + SWA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Hq, Hkv, Lq, Lk, D, causal, window, bq, bk = case
    rng = np.random.default_rng(Lq + D)
    q = jnp.asarray(rng.standard_normal((B, Hq, Lq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)), dtype)
    got = attention(q, k, v, causal=causal, window=window,
                    block_q=bq, block_k=bk, interpret=True)
    want = mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_grads_finite():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)

    def loss(q, k, v):
        return jnp.sum(attention_trainable(q, k, v, True, None, True) ** 2)

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert all(bool(jnp.isfinite(g).all()) for g in (gq, gk, gv))
    # and the fwd value matches the ref the bwd is derived from
    np.testing.assert_allclose(
        attention_trainable(q, k, v, True, None, True),
        mha_ref(q, k, v, causal=True), rtol=2e-5, atol=2e-5)
