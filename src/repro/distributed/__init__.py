from . import sharding
from .sharding import (
    MeshSpec, as_mesh, choose_pspec, logical_constraint, mesh_context,
    mesh_fingerprint, named_sharding, resolve_time_mesh, tree_pspecs,
    tree_shardings,
)
