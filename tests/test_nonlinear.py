"""Iterated (parallel) MAP estimation on the coordinated-turn model (5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Estimator,
    IteratedOptions,
    ParallelOptions,
    Problem,
    SequentialOptions,
    TwoFilterOptions,
    om_cost_nonlinear,
    simulate_nonlinear,
    time_grid,
)

from helpers import coordinated_turn


@pytest.fixture(scope="module")
def ct_problem():
    model = coordinated_turn()
    N = 640
    ts = time_grid(0.0, 5.0, N)
    xs, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(2))
    return model, ts, xs, y


def _ieks(model, method, inner, **outer):
    return Estimator(model, method=method,
                     options=IteratedOptions(inner=inner, **outer))


def test_parallel_equals_sequential_ieks(ct_problem):
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    par = _ieks(model, "parallel_rts",
                ParallelOptions(nsub=10, mode="discrete"),
                iterations=5).solve(problem)
    seq = _ieks(model, "sequential_rts",
                SequentialOptions(mode="discrete"),
                iterations=5).solve(problem)
    np.testing.assert_allclose(par.x, seq.x, rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(par.cost_trace, seq.cost_trace,
                               rtol=1e-8, atol=1e-8)


def test_cost_trace_is_gauss_newton_descent(ct_problem):
    """Solution.cost_trace: one entry per linearise+solve pass, matching
    the true nonlinear OM cost of each iterate, and with
    cost == cost_trace[-1].  Gauss-Newton is not guaranteed monotone on
    the first pass (the prior-mean linearisation point is far off), so we
    require descent overall and from iteration 2 on."""
    model, ts, _, y = ct_problem
    sol = _ieks(model, "parallel_rts",
                ParallelOptions(nsub=10, mode="discrete"),
                iterations=5).solve(Problem.single(model, ts, y))
    trace = np.asarray(sol.cost_trace)
    assert trace.shape == (5,)
    assert float(sol.cost) == trace[-1]
    assert trace[-1] < trace[0]
    assert np.all(np.diff(trace[1:]) <= 1e-4 * np.abs(trace[1:-1]))
    # the last entry IS the OM cost of the returned trajectory
    ref = float(om_cost_nonlinear(model, ts, y, sol.x))
    np.testing.assert_allclose(trace[-1], ref, rtol=1e-9)
    # and iteration counts agree with separately-run shorter solves
    for it in (1, 3):
        s = _ieks(model, "parallel_rts",
                  ParallelOptions(nsub=10, mode="discrete"),
                  iterations=it).solve(Problem.single(model, ts, y))
        np.testing.assert_allclose(np.asarray(s.cost_trace), trace[:it],
                                   rtol=1e-8)


def test_ieks_tracks_truth(ct_problem):
    model, ts, xs, y = ct_problem
    sol = _ieks(model, "parallel_rts",
                ParallelOptions(nsub=10, mode="discrete"),
                iterations=5).solve(Problem.single(model, ts, y))
    rmse = float(jnp.sqrt(jnp.mean((sol.x[:, :2] - xs[:, :2]) ** 2)))
    # positions are observed through (range, bearing) with tight noise
    assert rmse < 0.5, rmse


def test_euler_mode_ieks(ct_problem):
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    par = _ieks(model, "parallel_rts", ParallelOptions(nsub=10, mode="euler"),
                iterations=3).solve(problem)
    seq = _ieks(model, "sequential_rts", SequentialOptions(mode="euler"),
                iterations=3).solve(problem)
    assert float(jnp.max(jnp.abs(par.x - seq.x))) < 5e-2


def test_divergence_correction_runs(ct_problem):
    """the beyond-paper Onsager-Machlup divergence knob must run and stay
    close to the uncorrected solution (div f = 0 for coordinated turn!)."""
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    inner = ParallelOptions(nsub=10, mode="discrete")
    a = _ieks(model, "parallel_rts", inner, iterations=2).solve(problem)
    b = _ieks(model, "parallel_rts", inner, iterations=2,
              divergence_correction=True).solve(problem)
    # f = (v, -w zdot, w xidot, 0): div f = d(-w zdot)/dzdot ... = 0 + w - w = 0
    np.testing.assert_allclose(a.x, b.x, rtol=1e-7, atol=1e-7)


def test_two_filter_ieks(ct_problem):
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    rts = _ieks(model, "parallel_rts",
                ParallelOptions(nsub=10, mode="discrete"),
                iterations=3).solve(problem)
    tf = _ieks(model, "parallel_two_filter",
               TwoFilterOptions(nsub=10, mode="discrete"),
               iterations=3).solve(problem)
    np.testing.assert_allclose(tf.x, rts.x, rtol=1e-5, atol=1e-5)


def test_x_init_warm_start(ct_problem):
    """A converged trajectory as x_init must keep the solution at the
    optimum in one pass; a single-point x_init must broadcast."""
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    inner = ParallelOptions(nsub=10, mode="discrete")
    ref = _ieks(model, "parallel_rts", inner, iterations=5).solve(problem)
    warm = _ieks(model, "parallel_rts", inner, iterations=1).solve(
        Problem.single(model, ts, y, x_init=ref.x))
    # one extra pass from the 5-iteration point still moves x by ~1e-6
    # (the IEKS fixed point is only approached); bound the drift, don't
    # demand exact stationarity.
    np.testing.assert_allclose(warm.x, ref.x, atol=1e-5, rtol=0)
    point = _ieks(model, "parallel_rts", inner, iterations=1).solve(
        Problem.single(model, ts, y, x_init=model.m0))
    cold = _ieks(model, "parallel_rts", inner, iterations=1).solve(problem)
    np.testing.assert_allclose(point.x, cold.x, rtol=1e-9, atol=1e-9)
