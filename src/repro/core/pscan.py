"""Parallel associative scans: local (on-chip) and distributed (multi-chip).

The paper's span-reduction comes from ``jax.lax.associative_scan`` (Blelloch
[5]).  Orientation conventions (critical for the non-commutative operators of
``combine.py``):

* ``prefix_scan(fn, a)[i]  = a_0 (x) a_1 (x) ... (x) a_i``  (eq. 25)
* ``suffix_scan(fn, a)[i]  = a_i (x) a_{i+1} (x) ... (x) a_{T-1}``  (eq. 26)

where ``fn(x, y)`` always receives ``x`` as the EARLIER-interval operand.
``jax.lax.associative_scan(reverse=True)`` flips the sequence but keeps the
operand order, which would silently transpose non-commutative operators; the
wrappers below handle the swap explicitly and are property-tested against
sequential folds.

``distributed_scan`` shards the time axis across a mesh axis (inside
``shard_map``): local scan -> all-gather of the P per-shard carries ->
redundant small scan over carries -> local fix-up.  Work O(T/P + P) per
device, span O(log(T/P) + P) with one all-gather; this is the multi-pod
temporal decomposition described in DESIGN.md S3.

``sharded_scan`` is the TOP-LEVEL entry around it (used by
``method="distributed"``): it owns the ``shard_map`` wrapping, handles
scan lengths that do not divide the shard count (a divisible head runs
distributed, the remainder tail runs locally and is folded in with one
broadcast combine), and degrades to the plain on-chip scan when the mesh
axis has fewer than 2 devices or the scan is too short to shard.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, TypeVar

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro import obs

try:                                   # jax >= 0.6 top-level API
    from jax import shard_map as _shard_map
except ImportError:                    # older releases
    from jax.experimental.shard_map import shard_map as _shard_map

T = TypeVar("T")


def prefix_scan(fn: Callable[[T, T], T], elems: T, *, sequential: bool = False) -> T:
    """Inclusive prefix combine along axis 0 (earlier operand first)."""
    if sequential:
        return _sequential_prefix(fn, elems)
    return jax.lax.associative_scan(fn, elems, axis=0)


def suffix_scan(fn: Callable[[T, T], T], elems: T, *, sequential: bool = False) -> T:
    """Inclusive suffix combine along axis 0 (earlier operand first)."""
    if sequential:
        return _sequential_suffix(fn, elems)
    flipped = jax.tree_util.tree_map(lambda x: jnp.flip(x, axis=0), elems)
    swapped = lambda a, b: fn(b, a)
    out = jax.lax.associative_scan(swapped, flipped, axis=0)
    return jax.tree_util.tree_map(lambda x: jnp.flip(x, axis=0), out)


def _sequential_prefix(fn, elems):
    """O(T)-span reference fold (the paper's sequential baseline shape)."""
    first = jax.tree_util.tree_map(lambda x: x[0], elems)
    rest = jax.tree_util.tree_map(lambda x: x[1:], elems)

    def step(carry, e):
        nxt = fn(carry, e)
        return nxt, nxt

    _, tail = jax.lax.scan(step, first, rest)
    return jax.tree_util.tree_map(
        lambda f, t: jnp.concatenate([f[None], t], axis=0), first, tail
    )


def _sequential_suffix(fn, elems):
    last = jax.tree_util.tree_map(lambda x: x[-1], elems)
    rest = jax.tree_util.tree_map(lambda x: x[:-1], elems)

    def step(carry, e):
        nxt = fn(e, carry)
        return nxt, nxt

    _, head = jax.lax.scan(step, last, rest, reverse=True)
    return jax.tree_util.tree_map(
        lambda h, l: jnp.concatenate([h, l[None]], axis=0), head, last
    )


def _select_tree(pred, a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(pred, x, y), a, b
    )


def distributed_scan(
    fn: Callable[[T, T], T],
    elems: T,
    axis_name: str,
    *,
    reverse: bool = False,
    carry_dtype=None,
) -> T:
    """Associative scan over a time axis sharded across ``axis_name``.

    Must be called INSIDE ``shard_map``; ``elems`` is the local shard with
    the local time axis at position 0.  Returns the local shard of the
    global inclusive prefix (or suffix if ``reverse``).

    ``carry_dtype`` (optional) runs the redundant scan over the
    all-gathered per-shard carries in that dtype (e.g. ``jnp.float64``
    for float32 elements: the carry chain is the one O(P)-sequential
    composition, so it accumulates the most round-off), casting back to
    the element dtypes before the local fix-up combine.

    No identity element is required: shard 0 (resp. the last shard for the
    reverse scan) keeps its local result via a masked select.
    """
    local = suffix_scan(fn, elems) if reverse else prefix_scan(fn, elems)
    carry = jax.tree_util.tree_map(
        lambda x: x[0] if reverse else x[-1], local
    )
    # (P, ...) per-shard totals, replicated on every shard.
    totals = jax.lax.all_gather(carry, axis_name, axis=0, tiled=False)
    if carry_dtype is not None:
        dtypes = jax.tree_util.tree_map(lambda x: x.dtype, totals)
        totals = jax.tree_util.tree_map(
            lambda x: x.astype(carry_dtype), totals)
    idx = jax.lax.axis_index(axis_name)
    # psum of 1 == the axis size; jax.lax.axis_size is not available on
    # every supported jax release, psum works inside shard_map on all.
    p = jax.lax.psum(1, axis_name)

    if reverse:
        # exclusive suffix of totals strictly AFTER this shard
        suff = suffix_scan(fn, totals, sequential=True)
        nxt = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(
                x, jnp.minimum(idx + 1, p - 1), axis=0, keepdims=False
            ),
            suff,
        )
        if carry_dtype is not None:
            nxt = jax.tree_util.tree_map(
                lambda x, dt: x.astype(dt), nxt, dtypes)
        # fn broadcasts the rank-reduced carry against the local time axis.
        combined = fn(local, nxt)
        return _select_tree(idx == p - 1, local, combined)

    pref = prefix_scan(fn, totals, sequential=True)
    prev = jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(
            x, jnp.maximum(idx - 1, 0), axis=0, keepdims=False
        ),
        pref,
    )
    if carry_dtype is not None:
        prev = jax.tree_util.tree_map(
            lambda x, dt: x.astype(dt), prev, dtypes)
    combined = fn(prev, local)
    return _select_tree(idx == 0, local, combined)


def sharded_scan(
    fn: Callable[[T, T], T],
    elems: T,
    *,
    mesh,
    axis_name: str,
    reverse: bool = False,
    carry_dtype=None,
) -> T:
    """Top-level time-axis-sharded associative scan (any length T).

    Owns the ``shard_map`` around :func:`distributed_scan` over
    ``mesh``'s ``axis_name`` axis.  A scan length that does not divide
    the shard count P is split: the largest P-divisible head runs
    distributed, the remainder tail (< P elements) runs locally and is
    folded in with one broadcast combine -- results match the on-chip
    scan orientation conventions exactly.  Degrades to the plain local
    scan when P < 2 or T < 2 P (nothing to shard / shards would be
    shorter than the carry chain).

    With ``repro.obs`` enabled, each TRACE of a sharded scan counts
    ``distributed.shards`` (time-shards used) and
    ``distributed.carry_bytes`` (bytes of per-shard carries all-gathered
    onto every device), and spans ``span.distributed_scan`` -- static
    shapes, so cached executables do not re-count (same convention as the
    ``kernel.*`` counters, see docs/OBSERVABILITY.md).
    """
    tm = jax.tree_util.tree_map
    leaves = jax.tree_util.tree_leaves(elems)
    length = leaves[0].shape[0]
    shards = mesh.shape[axis_name]
    if shards < 2 or length < 2 * shards:
        return suffix_scan(fn, elems) if reverse else prefix_scan(fn, elems)

    with obs.trace_span("distributed_scan"):
        if obs.enabled():
            carry = sum(
                l.dtype.itemsize * math.prod(l.shape[1:]) for l in leaves)
            obs.inc("distributed.shards", shards)
            obs.inc("distributed.carry_bytes", carry * shards)

        spec = tm(lambda _: PartitionSpec(axis_name), elems)
        dist = _shard_map(
            partial(distributed_scan, fn, axis_name=axis_name,
                    reverse=reverse, carry_dtype=carry_dtype),
            mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False)

        cut = (length // shards) * shards
        if cut == length:
            return dist(elems)
        # Non-divisible T: distributed head + local tail, one broadcast
        # combine to stitch (fn broadcasts a rank-reduced operand).
        head = tm(lambda x: x[:cut], elems)
        tail = tm(lambda x: x[cut:], elems)
        cat = lambda a, b: jnp.concatenate([a, b], axis=0)
        if reverse:
            tail_suf = suffix_scan(fn, tail)
            tail_total = tm(lambda x: x[0], tail_suf)
            head_out = fn(dist(head), tail_total)
            return tm(cat, head_out, tail_suf)
        head_out = dist(head)
        head_total = tm(lambda x: x[-1], head_out)
        tail_out = fn(head_total, prefix_scan(fn, tail))
        return tm(cat, head_out, tail_out)
