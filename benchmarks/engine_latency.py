"""Serving-latency benchmark: ``TrajectoryEngine`` tracks/sec and
per-record latency percentiles.

The paper's axis is per-problem span; the serving question is different:
how many concurrent tracks does one engine drain, and what does one
submitted record wait end-to-end?  This drives a deterministic ragged
workload (fixed seed, fixed length mix spanning several pad buckets)
through ``TrajectoryEngine`` twice -- a warmup drain that compiles the
per-bucket executables, then the measured drain running entirely on
cache hits -- and reports tracks/sec (measured drain) plus the p50/p99
of the ``engine.record_latency_seconds`` obs histogram (submit-to-done
wall time per record; the histogram covers both drains, so p99 exposes
compile-inflated first-wave latency while p50 reflects steady state).

The padding-waste and cache-hit-rate numbers this workload feeds into
``repro.obs.snapshot()`` are deterministic, which is what lets
``benchmarks/compare.py`` hard-gate them in CI while timing stays
warn-only.

    PYTHONPATH=src python benchmarks/engine_latency.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np


def _records(lengths, ny, rng):
    out = []
    for n in lengths:
        ts = np.linspace(0.0, n / 32.0, n + 1, dtype=np.float32)
        y = rng.standard_normal((n, ny)).astype(np.float32)
        out.append((ts, y))
    return out


def run(smoke=False, batch=8, nsub=10, mode="discrete",
        method="parallel_rts", seed=0):
    import repro.obs as obs
    from repro.configs.wiener_velocity import WienerVelocityConfig
    from repro.core import get_method
    from repro.serving import TrajectoryEngine

    wcfg = WienerVelocityConfig(p0=1.0)
    model = wcfg.model()
    if smoke:
        batch = 4
        # two pad buckets (20 and 40 intervals at nsub=10)
        lengths = [12, 25, 18, 33, 14, 40, 20, 27]
    else:
        lengths = list(np.random.default_rng(seed).choice(
            [80, 120, 160, 250, 320, 500], size=64))
    rng = np.random.default_rng(seed)
    ny = np.asarray(model.H).shape[0]      # constant H in this config
    recs = _records(lengths, ny, rng)

    options = get_method(method).options_cls.from_legacy(
        nsub=nsub, mode=mode)
    engine = TrajectoryEngine(model, batch=batch, method=method,
                              options=options)
    engine.estimate(recs)               # warmup: compiles every bucket

    t0 = time.perf_counter()
    engine.estimate(recs)               # measured: cache hits only
    dt = time.perf_counter() - t0

    derived = f"tracks_per_sec={len(recs) / dt:.1f}"
    if obs.enabled():
        lat = obs.histogram("engine.record_latency_seconds").summary()
        if lat.get("count"):
            derived += (f",p50_ms={lat['p50'] * 1e3:.2f}"
                        f",p99_ms={lat['p99'] * 1e3:.2f}")
        waste = obs.gauge("engine.padding_waste").value
        derived += f",waste={waste:.3f}"
    return [{
        "name": f"serve/engine/B{batch}_R{len(recs)}",
        "us_per_call": dt / len(recs) * 1e6,
        "derived": derived,
    }]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI bit-rot check)")
    args = ap.parse_args()
    import repro.obs as obs
    obs.enable()
    for r in run(smoke=args.smoke):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
