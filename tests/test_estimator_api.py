"""The unified Estimator/Problem/Solution surface: registry error paths,
construction-time option validation, problem validation, diagnostics, the
AOT ``lower`` path, and live method registration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import coordinated_turn, random_ltv, wiener_velocity
from repro.core import (
    Estimator,
    IteratedOptions,
    ParallelOptions,
    Problem,
    SequentialOptions,
    SolverOptions,
    TwoFilterOptions,
    get_method,
    method_names,
    om_cost_linear,
    register_method,
    sequential_rts,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)

NSUB = 5


@pytest.fixture(scope="module")
def linear_problem():
    model = wiener_velocity()
    ts = time_grid(0.0, 1.0, 4 * NSUB)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    return model, ts, y


# -- registry error paths ---------------------------------------------------


def test_unknown_method_name(linear_problem):
    model, _, _ = linear_problem
    with pytest.raises(ValueError, match="method must be one of"):
        Estimator(model, method="no_such_method")
    with pytest.raises(ValueError, match="no_such_method"):
        get_method("no_such_method")


def test_duplicate_registration_requires_overwrite():
    register_method("_dup_test", lambda g, o: sequential_rts(g, o.mode),
                    SequentialOptions, overwrite=True)
    with pytest.raises(ValueError, match="already registered"):
        register_method("_dup_test", lambda g, o: None, SequentialOptions)
    # overwrite=True replaces silently
    register_method("_dup_test", lambda g, o: sequential_rts(g, o.mode),
                    SequentialOptions, overwrite=True)
    assert "_dup_test" in method_names()


def test_register_method_rejects_bad_options_cls():
    with pytest.raises(TypeError, match="SolverOptions subclass"):
        register_method("_bad_opts", lambda g, o: None, dict,
                        overwrite=True)


def test_registered_method_is_solvable(linear_problem):
    model, ts, y = linear_problem
    register_method("_seq_alias", lambda g, o: sequential_rts(g, o.mode),
                    SequentialOptions, overwrite=True)
    problem = Problem.single(model, ts, y)
    sol = Estimator(model, method="_seq_alias",
                    options=SequentialOptions(mode="discrete")).solve(problem)
    ref = Estimator(model, method="sequential_rts",
                    options=SequentialOptions(mode="discrete")).solve(problem)
    np.testing.assert_allclose(sol.x, ref.x, atol=1e-12, rtol=0)


# -- option validation (construction time) ----------------------------------


def test_unknown_option_field_errors():
    with pytest.raises(TypeError):
        ParallelOptions(nsubb=10)            # typo'd field
    with pytest.raises(TypeError):
        SequentialOptions(nsub=10)           # field of a DIFFERENT method
    with pytest.raises(TypeError):
        IteratedOptions(iteration=3)


def test_option_value_validation():
    with pytest.raises(ValueError, match="mode"):
        ParallelOptions(mode="bogus")
    with pytest.raises(ValueError, match="nsub"):
        ParallelOptions(nsub=0)
    with pytest.raises(ValueError, match="iterations"):
        IteratedOptions(iterations=0)
    with pytest.raises(ValueError, match="block0_fill"):
        TwoFilterOptions(block0_fill="nope")
    with pytest.raises(TypeError, match="inner"):
        IteratedOptions(inner="parallel_rts")


def test_options_are_frozen_and_hashable():
    o = ParallelOptions(nsub=7, mode="discrete")
    with pytest.raises(dataclasses.FrozenInstanceError):
        o.nsub = 3
    assert hash(o) == hash(ParallelOptions(nsub=7, mode="discrete"))
    assert o.replace(nsub=3).nsub == 3


def test_estimator_rejects_mismatched_options(linear_problem):
    model, _, _ = linear_problem
    with pytest.raises(TypeError, match="TwoFilterOptions"):
        Estimator(model, method="parallel_two_filter",
                  options=ParallelOptions())
    with pytest.raises(TypeError, match="IteratedOptions is for Nonlinear"):
        Estimator(model, method="parallel_rts", options=IteratedOptions())
    ct = coordinated_turn()
    with pytest.raises(TypeError, match="inner"):
        Estimator(ct, method="parallel_rts",
                  options=IteratedOptions(inner=SequentialOptions()))
    # bare inner options are auto-wrapped for nonlinear models
    est = Estimator(ct, method="parallel_rts",
                    options=ParallelOptions(nsub=NSUB))
    assert isinstance(est.options, IteratedOptions)
    assert est.options.inner == ParallelOptions(nsub=NSUB)
    assert est.block_size == NSUB


# -- problem validation ------------------------------------------------------


def test_measurement_mask_validation(linear_problem):
    model, ts, y = linear_problem
    N = y.shape[0]
    with pytest.raises(ValueError, match="measurement_mask"):
        Problem.single(model, ts, y,
                       measurement_mask=jnp.ones(N - 1))   # wrong length
    with pytest.raises(ValueError, match="0/1 array"):     # wrong dtype
        Problem.single(model, ts, y,
                       measurement_mask=jnp.ones(N, dtype=jnp.complex64))
    with pytest.raises(ValueError, match="measurement_mask"):
        Problem.stacked(model, ts, y[None],
                        measurement_mask=jnp.ones(N))      # needs (B, N)
    ok = Problem.single(model, ts, y, measurement_mask=jnp.ones(N))
    assert ok.measurement_mask.shape == (N,)
    # integer/bool 0/1 masks are cast to float, not rejected
    as_int = Problem.single(model, ts, y,
                            measurement_mask=np.ones(N, dtype=np.int32))
    assert jnp.issubdtype(as_int.measurement_mask.dtype, jnp.floating)
    as_bool = Problem.single(model, ts, y,
                             measurement_mask=np.ones(N, dtype=bool))
    assert jnp.issubdtype(as_bool.measurement_mask.dtype, jnp.floating)


def test_x_init_validation(linear_problem):
    model, ts, y = linear_problem
    with pytest.raises(ValueError, match="NonlinearSDE"):
        Problem.single(model, ts, y, x_init=jnp.zeros(model.nx))
    ct = coordinated_turn()
    ts3 = time_grid(0.0, 1.0, 4 * NSUB)
    _, y3 = simulate_nonlinear(ct, ts3, jax.random.PRNGKey(3))
    with pytest.raises(ValueError, match="x_init"):
        Problem.single(ct, ts3, y3, x_init=jnp.zeros(3))   # wrong nx
    with pytest.raises(ValueError, match="x_init"):
        Problem.stacked(ct, ts3, y3[None],
                        x_init=jnp.zeros((2, ct.nx)))      # wrong batch


def test_problem_model_must_match_estimator(linear_problem):
    model, ts, y = linear_problem
    other = wiener_velocity()
    est = Estimator(model, method="sequential_rts")
    with pytest.raises(ValueError, match="model"):
        est.solve(Problem.single(other, ts, y))


def test_ragged_record_validation():
    model = wiener_velocity()
    with pytest.raises(ValueError, match="non-empty"):
        Problem.ragged(model, [])
    ts = np.linspace(0.0, 1.0, 11)
    y = np.zeros((10, 2))
    with pytest.raises(ValueError, match="record 1"):
        Problem.ragged(model, [(ts, y), (ts[:-1], y)])


# -- diagnostics & AOT -------------------------------------------------------


def test_solution_cost_matches_om_cost():
    """Solution.cost == the om_cost_linear objective (invertible-Q model,
    where pinv == inv and the quadratures match term by term)."""
    model = random_ltv(jax.random.PRNGKey(7))
    ts = time_grid(0.0, 2.0, 4 * NSUB)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(1))
    sol = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=NSUB, mode="discrete")
                    ).solve(Problem.single(model, ts, y))
    ref = float(om_cost_linear(model, ts, y, sol.x))
    np.testing.assert_allclose(float(sol.cost), ref, rtol=1e-9)


def test_lower_compile_aot(linear_problem):
    model, ts, y = linear_problem
    est = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=NSUB, mode="discrete"))
    problem = Problem.single(model, ts, y)
    compiled = est.lower(problem).compile()
    sol_aot = compiled(ts, y)
    sol = est.solve(problem)
    np.testing.assert_array_equal(np.asarray(sol_aot.x), np.asarray(sol.x))
    recs = [(np.asarray(ts), np.asarray(y))]
    with pytest.raises(ValueError, match="ragged"):
        est.lower(Problem.ragged(model, recs))


def test_solver_options_base_rejects_bad_mode():
    with pytest.raises(ValueError):
        SolverOptions(mode="")


def test_cache_distinguishes_mask_from_x_init():
    """Regression: a (N,) float mask and an (nx,) x_init with nx == N have
    identical argument shapes/dtypes; the cache key must still separate
    the two executables (it keys on has_mask/has_xinit, not just shapes).
    """
    from repro.core import ExecutableCache, cache_stats

    model = coordinated_turn()            # nx = 5
    ts = time_grid(0.0, 1.0, 5)           # N = 5 == nx
    _, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(4))
    est = Estimator(model, method="sequential_rts",
                    options=IteratedOptions(
                        iterations=2, inner=SequentialOptions(mode="euler")))
    mask = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0])   # drops two intervals
    x0 = jnp.asarray(model.m0)
    assert mask.shape == x0.shape and mask.dtype == x0.dtype

    before = cache_stats()
    masked = est.solve(Problem.single(model, ts, y, measurement_mask=mask))
    warmed = est.solve(Problem.single(model, ts, y, x_init=x0))
    after = cache_stats()
    assert after["misses"] == before["misses"] + 2   # two executables

    # and the x_init solve matches a fresh private-cache estimator (i.e. it
    # did NOT run through the masked executable)
    fresh = Estimator(model, method="sequential_rts",
                      options=IteratedOptions(
                          iterations=2,
                          inner=SequentialOptions(mode="euler")),
                      cache=ExecutableCache())
    ref = fresh.solve(Problem.single(model, ts, y, x_init=x0))
    np.testing.assert_array_equal(np.asarray(warmed.x), np.asarray(ref.x))
    assert not np.allclose(np.asarray(masked.x), np.asarray(warmed.x))


def test_diagnostics_opt_out(linear_problem):
    model, ts, y = linear_problem
    problem = Problem.single(model, ts, y)
    options = ParallelOptions(nsub=NSUB, mode="discrete")
    lean = Estimator(model, method="parallel_rts", options=options,
                     diagnostics=False).solve(problem)
    full = Estimator(model, method="parallel_rts",
                     options=options).solve(problem)
    assert lean.cost is None and lean.cost_trace is None
    assert full.cost is not None
    np.testing.assert_array_equal(np.asarray(lean.x), np.asarray(full.x))
    # nonlinear: no cost trace either
    ct = coordinated_turn()
    ts3 = time_grid(0.0, 1.0, 4 * NSUB)
    _, y3 = simulate_nonlinear(ct, ts3, jax.random.PRNGKey(5))
    lean_nl = Estimator(ct, method="parallel_rts",
                        options=IteratedOptions(
                            iterations=2,
                            inner=ParallelOptions(nsub=NSUB)),
                        diagnostics=False).solve(Problem.single(ct, ts3, y3))
    assert lean_nl.cost is None and lean_nl.cost_trace is None
    assert bool(jnp.isfinite(lean_nl.x).all())
