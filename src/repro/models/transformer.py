"""The model zoo stack: decoder/encoder LMs with attn / ssm / hybrid mixers.

One generic implementation covers all ten assigned architectures (dense GQA,
SWA, qk-norm, MoE, mamba2, hymba-style parallel hybrid, encoder-only, and
embedding-input VLM/audio backbones).  Weights are stacked over layers and
the stack is a ``lax.scan`` (+ optional ``jax.checkpoint``) so the HLO is
O(1) in depth -- essential for 60-layer production compiles.

Public entry points (all pure functions):
  init / axes / shapes        parameter tree + logical sharding metadata
  train_loss                  tokens/embeddings -> scalar loss
  prefill                     full-sequence forward -> logits + caches
  decode_step                 one token with caches -> logits + caches
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import logical_constraint

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    P, activation, init_params, params_axes, params_shapes, rms_norm,
    stack_specs,
)


def _mlp_spec(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    spec = {
        "wu": P((D, F), ("embed", "ff")),
        "wd": P((F, D), ("ff", "embed")),
    }
    if cfg.mlp_type == "gated":
        spec["wg"] = P((D, F), ("embed", "ff"))
    return spec


def layer_spec(cfg: ModelConfig) -> dict:
    spec: dict = {"ln1": P((cfg.d_model,), ("embed",), init="ones")}
    if cfg.mixer in ("attn", "hybrid"):
        spec["attn"] = attn_mod.attn_spec(cfg)
    if cfg.mixer in ("ssm", "hybrid"):
        spec["ssm"] = ssm_mod.ssm_spec(cfg)
    if cfg.mixer == "hybrid":
        spec["attn_out_norm"] = P((cfg.d_model,), ("embed",), init="ones")
        spec["ssm_out_norm"] = P((cfg.d_model,), ("embed",), init="ones")
    if cfg.is_moe:
        spec["ln2"] = P((cfg.d_model,), ("embed",), init="ones")
        spec["moe"] = moe_mod.moe_spec(cfg)
    elif cfg.mlp_type != "none" and cfg.d_ff > 0:
        spec["ln2"] = P((cfg.d_model,), ("embed",), init="ones")
        spec["mlp"] = _mlp_spec(cfg)
    return spec


def model_spec(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    spec: dict = {
        "layers": stack_specs(layer_spec(cfg), cfg.num_layers),
        "final_norm": P((D,), ("embed",), init="ones"),
    }
    needs_embed = cfg.input_mode == "tokens" or not cfg.is_encoder
    if needs_embed:
        spec["embed"] = P((V, D), ("vocab", "embed_model"), fan_in=D)
    if not cfg.tie_embeddings:
        spec["lm_head"] = P((D, V), ("embed_model", "vocab"))
    return spec


def init(cfg: ModelConfig, key) -> dict:
    return init_params(key, model_spec(cfg), _dtype(cfg))


def axes(cfg: ModelConfig) -> dict:
    return params_axes(model_spec(cfg))


def shapes(cfg: ModelConfig) -> dict:
    return params_shapes(model_spec(cfg))


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_mlp(p, x, cfg):
    act = activation(cfg.act)
    h = jnp.einsum("bld,df->blf", x, p["wu"])
    if cfg.mlp_type == "gated":
        g = jnp.einsum("bld,df->blf", x, p["wg"])
        h = act(g) * h
    else:
        h = act(h)
    h = logical_constraint(h, "batch", None, "ff")
    out = jnp.einsum("blf,fd->bld", h, p["wd"])
    return logical_constraint(out, "batch", None, None)


def _layer_forward(p, x, cfg: ModelConfig, positions, use_kernel,
                   interpret, causal_skip):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mixer == "attn":
        mix = attn_mod.attention_forward(
            p["attn"], h, cfg, positions, use_kernel=use_kernel,
            interpret=interpret, causal_skip=causal_skip)
    elif cfg.mixer == "ssm":
        mix = ssm_mod.ssm_forward(p["ssm"], h, cfg, use_kernel=use_kernel,
                                  interpret=interpret)
    else:  # hybrid: parallel attn + ssm heads, normalised then averaged
        a = attn_mod.attention_forward(
            p["attn"], h, cfg, positions, use_kernel=use_kernel,
            interpret=interpret, causal_skip=causal_skip)
        s = ssm_mod.ssm_forward(p["ssm"], h, cfg, use_kernel=use_kernel,
                                interpret=interpret)
        mix = 0.5 * (rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rms_norm(s, p["ssm_out_norm"], cfg.norm_eps))
    x = x + mix
    if "moe" in p:
        x = x + moe_mod.moe_forward(
            p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    elif "mlp" in p:
        x = x + _apply_mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                           cfg)
    if cfg.seq_parallel:
        # megatron-style SP: the residual stream lives sequence-sharded
        # over the model axis between blocks (AR -> RS+AG at TP edges)
        x = logical_constraint(x, "batch", "seq_sp", None)
    return x


def _stack_forward(params, x, cfg: ModelConfig, positions, *,
                   use_kernel=False, interpret=False, causal_skip=False):
    fn = functools.partial(
        _layer_forward, cfg=cfg, positions=positions,
        use_kernel=use_kernel, interpret=interpret,
        causal_skip=causal_skip)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    if cfg.unroll_layers:  # loop-free lowering for cost-model validation
        for i in range(cfg.num_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x = fn(lp, x)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def body(carry, lp):
        return fn(lp, carry), None

    g = cfg.remat_group
    if g and cfg.num_layers % g == 0 and cfg.num_layers > g:
        # sqrt-remat: outer scan over layer groups, checkpointed group
        # bodies re-run their inner scan during backward
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((cfg.num_layers // g, g) + a.shape[1:]),
            params["layers"])

        @jax.checkpoint
        def group_body(carry, gp):
            out, _ = jax.lax.scan(body, carry, gp)
            return out, None

        x, _ = jax.lax.scan(group_body, x, grouped)
    else:
        x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _embed_in(params, batch, cfg: ModelConfig):
    if cfg.input_mode == "embeddings":
        x = batch["embeddings"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    return logical_constraint(x, "batch", None, None)


def _lm_logits(params, x, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bld,dv->blv", x, head)
    if cfg.padded_vocab != cfg.vocab_size:
        # physical vocab padding (divisible TP sharding): mask pad columns
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logical_constraint(logits, "batch", None, "vocab")


def train_loss(params, batch, cfg: ModelConfig, *, use_kernel=False,
               interpret=False, causal_skip=False,
               moe_aux_weight: float = 0.01):
    """Next-token (decoder) or masked-position (encoder) cross-entropy."""
    x = _embed_in(params, batch, cfg)
    B, L = x.shape[:2]
    positions = jnp.arange(L, dtype=jnp.float32)
    h = _stack_forward(params, x, cfg, positions, use_kernel=use_kernel,
                       interpret=interpret, causal_skip=causal_skip)
    logits = _lm_logits(params, h, cfg)
    labels = batch["labels"]  # < vocab_size, never a pad column
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.is_moe:
        # router balance aux (first layer's router as the probe, standard)
        aux = moe_mod.moe_aux_loss(
            jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"]),
            x, cfg)
        loss = loss + moe_aux_weight * aux
    return loss.astype(jnp.float32)


class LayerCaches(NamedTuple):
    attn: Optional[Any] = None
    ssm: Optional[Any] = None


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (num_layers-leading) caches for the decode scan."""
    dt = _dtype(cfg)

    def stack(c):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None],
                                       (cfg.num_layers,) + a.shape).copy(),
            c)

    attn = ssmc = None
    if cfg.mixer in ("attn", "hybrid"):
        attn = stack(attn_mod.init_kv_cache(cfg, batch, max_len, dt))
    if cfg.mixer in ("ssm", "hybrid"):
        ssmc = stack(ssm_mod.init_ssm_cache(cfg, batch, dt))
    return LayerCaches(attn, ssmc)


def prefill(params, batch, cfg: ModelConfig, max_len: int, *,
            use_kernel=False, interpret=False):
    """Full-sequence forward that also populates decode caches.

    For simplicity and compile-size the caches are built by re-running the
    per-layer mixers in cache-filling mode inside the same scan.
    """
    x = _embed_in(params, batch, cfg)
    B, L = x.shape[:2]
    positions = jnp.arange(L, dtype=jnp.float32)
    caches = init_caches(cfg, B, max_len)

    fn = functools.partial(
        _prefill_layer, cfg=cfg, positions=positions, max_len=max_len,
        use_kernel=use_kernel, interpret=interpret)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def body(carry, scanned):
        lp, cache = scanned
        x, new_cache = fn(lp, cache, carry)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, h[:, -1:], cfg)
    return logits, new_caches


def _prefill_layer(p, cache: LayerCaches, x, *, cfg, positions, max_len,
                   use_kernel, interpret):
    B, L, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_attn = new_ssm = None

    def fill_kv(h):
        k = jnp.einsum("bld,dhk->blhk", h, p["attn"]["wk"])
        v = jnp.einsum("bld,dhk->blhk", h, p["attn"]["wv"])
        if cfg.qk_norm:
            k = rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
        from .layers import apply_rope, rope_freqs
        cos, sin = rope_freqs(positions, cfg.hd, cfg.rope_theta)
        k = apply_rope(k, cos[:, None], sin[:, None])
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        W = cache.attn.k.shape[2] if cache.attn is not None else max_len
        if L >= W:   # keep the last W positions (rolling window)
            kk, vv = k[:, :, -W:], v[:, :, -W:]
            kc = jnp.zeros_like(cache.attn.k).at[:, :, :kk.shape[2]].set(kk)
            vc = jnp.zeros_like(cache.attn.v).at[:, :, :vv.shape[2]].set(vv)
        else:
            kc = jnp.zeros_like(cache.attn.k).at[:, :, :L].set(k)
            vc = jnp.zeros_like(cache.attn.v).at[:, :, :L].set(v)
        return attn_mod.KVCache(kc, vc, jnp.asarray(L, jnp.int32))

    if cfg.mixer == "attn":
        mix = attn_mod.attention_forward(
            p["attn"], h, cfg, positions, use_kernel=use_kernel,
            interpret=interpret)
        new_attn = fill_kv(h)
    elif cfg.mixer == "ssm":
        mix, new_ssm = _ssm_prefill(p["ssm"], h, cfg, cache.ssm,
                                    use_kernel, interpret)
    else:
        a = attn_mod.attention_forward(
            p["attn"], h, cfg, positions, use_kernel=use_kernel,
            interpret=interpret)
        new_attn = fill_kv(h)
        s, new_ssm = _ssm_prefill(p["ssm"], h, cfg, cache.ssm,
                                  use_kernel, interpret)
        mix = 0.5 * (rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
                     + rms_norm(s, p["ssm_out_norm"], cfg.norm_eps))
    x = x + mix
    if "moe" in p:
        x = x + moe_mod.moe_forward(
            p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    elif "mlp" in p:
        x = x + _apply_mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                           cfg)
    return x, LayerCaches(new_attn, new_ssm)


def _ssm_prefill(p, h, cfg, cache, use_kernel, interpret):
    """Run the SSM over the sequence, then recompute the terminal state by
    one extra pass over the last chunk (cheap, keeps one code path)."""
    out = ssm_mod.ssm_forward(p, h, cfg, use_kernel=use_kernel,
                              interpret=interpret)
    # sequential state replay over the last conv window for the conv cache
    # and a full-state replay via a small scan for the SSD state:
    new_cache = _ssm_state_from_sequence(p, h, cfg, cache)
    return out, new_cache


def _ssm_state_from_sequence(p, h, cfg, cache):
    B, L, _ = h.shape
    _, xs, Bm, Cm, dt = ssm_mod.preconv_streams(p, h, cfg)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    K = cfg.ssm_conv
    tail = xbc[:, -(K - 1):] if L >= K - 1 else jnp.pad(
        xbc, ((0, 0), (K - 1 - L, 0), (0, 0)))
    w_cat, b_cat = ssm_mod.conv_cat_weights(p, cfg)
    xbc_c = ssm_mod._causal_conv(xbc, w_cat, b_cat)
    xbc_c = jax.nn.silu(xbc_c)
    din = cfg.ssm_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    xs, Bm, Cm = jnp.split(xbc_c, [din, din + gs], axis=-1)
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    G, S = cfg.ssm_groups, cfg.ssm_state
    xh = xs.reshape(B, L, H, Pd).astype(jnp.float32)
    Bg = Bm.reshape(B, L, G, S).astype(jnp.float32)
    dth = jax.nn.softplus(dt + p["dt_bias"][None, None]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    l = dth * A[None, None]
    # terminal state = sum_s exp(cumsum_rev) dt x B  (one associative pass)
    cum = jnp.cumsum(l, axis=1)
    wfin = jnp.exp(cum[:, -1:][..., :] - cum)                 # (B, L, H)
    w = (wfin * dth)[..., None] * xh                          # (B, L, H, P)
    rep = H // G
    wg = w.reshape(B, L, G, rep, Pd)
    state = jnp.einsum("blgrp,blgs->bgrps", wg, Bg)
    state = state.reshape(B, H, Pd, S)
    return ssm_mod.SSMCache(tail, state)


def decode_step(params, tokens, caches: LayerCaches, cfg: ModelConfig):
    """One decode step.  tokens: (B,) int32 -> logits (B, V), new caches."""
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    x = logical_constraint(x, "batch", None, None)

    def body(carry, scanned):
        lp, cache = scanned
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        new_attn = new_ssm = None
        if cfg.mixer == "attn":
            mix, new_attn = attn_mod.attention_decode(
                lp["attn"], h, cfg, cache.attn)
        elif cfg.mixer == "ssm":
            mix, new_ssm = ssm_mod.ssm_decode(lp["ssm"], h, cfg, cache.ssm)
        else:
            a, new_attn = attn_mod.attention_decode(
                lp["attn"], h, cfg, cache.attn)
            s, new_ssm = ssm_mod.ssm_decode(lp["ssm"], h, cfg, cache.ssm)
            mix = 0.5 * (rms_norm(a, lp["attn_out_norm"], cfg.norm_eps)
                         + rms_norm(s, lp["ssm_out_norm"], cfg.norm_eps))
        x = x + mix
        if "moe" in lp:
            x = x + moe_mod.moe_forward(
                lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        elif "mlp" in lp:
            x = x + _apply_mlp(
                lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x, LayerCaches(new_attn, new_ssm)

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, h, cfg)[:, 0]
    return logits, new_caches
