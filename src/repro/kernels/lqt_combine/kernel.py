"""Pallas TPU kernel for the batched LQT combine (paper eq. 42).

TPU adaptation (DESIGN.md S2): the elements are tiny (nx x nx with
nx <= ~8) but the scan feeds the operator BATCHES of element pairs (one per
tree node per level, times any outer batch).  A GPU implementation maps one
element to one thread block; on TPU we instead put the BATCH in the 128-wide
lane (minor) dimension and keep the matrix indices as tiny major dimensions:

    layout (nx, nx, TB): element (i, j) entries of TB elements live in one
    VREG row -> every small-matrix op becomes an elementwise VPU op over
    lanes, with static Python loops over i/j/k (nx is tiny and static).

The (I + C1 J2)^{-1} solve is an in-register Gauss-Jordan WITHOUT pivoting,
which is safe here: C1, J2 are symmetric PSD, so C1 J2 has real nonnegative
eigenvalues and every pivot of I + C1 J2 is >= 1 during elimination (the
paper's invertibility argument, section 4.1).

Block sizing: each grid step processes TB elements; all ten operand blocks
plus temporaries fit comfortably in VMEM for TB = 512, nx <= 8
(10 * nx^2 * TB * 4B ~ 1.3 MiB << 16 MiB VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams


def _matmat(X, Y, nx):
    """(nx, nx, TB) @ (nx, nx, TB) -> (nx, nx, TB), lanes = batch."""
    rows = []
    for i in range(nx):
        cols = []
        for k in range(nx):
            acc = X[i, 0] * Y[0, k]
            for j in range(1, nx):
                acc = acc + X[i, j] * Y[j, k]
            cols.append(acc)
        rows.append(jnp.stack(cols, axis=0))
    return jnp.stack(rows, axis=0)


def _matvec(X, v, nx):
    """(nx, nx, TB) @ (nx, TB) -> (nx, TB)."""
    rows = []
    for i in range(nx):
        acc = X[i, 0] * v[0]
        for j in range(1, nx):
            acc = acc + X[i, j] * v[j]
        rows.append(acc)
    return jnp.stack(rows, axis=0)


def _transpose(X):
    return jnp.swapaxes(X, 0, 1)


def _gauss_jordan_inverse(M, nx):
    """Unpivoted Gauss-Jordan on (nx, nx, TB); rows are lane vectors."""
    a = [[M[i, j] for j in range(nx)] for i in range(nx)]
    inv = [[jnp.where(i == j, jnp.ones_like(M[0, 0]),
                      jnp.zeros_like(M[0, 0]))
            for j in range(nx)] for i in range(nx)]
    for k in range(nx):
        piv = 1.0 / a[k][k]
        a[k] = [x * piv for x in a[k]]
        inv[k] = [x * piv for x in inv[k]]
        for i in range(nx):
            if i == k:
                continue
            f = a[i][k]
            a[i] = [x - f * y for x, y in zip(a[i], a[k])]
            inv[i] = [x - f * y for x, y in zip(inv[i], inv[k])]
    return jnp.stack([jnp.stack(r, axis=0) for r in inv], axis=0)


def _combine_kernel(A1, b1, C1, e1, J1, A2, b2, C2, e2, J2,
                    oA, ob, oC, oe, oJ, *, nx):
    A1v, C1v, J2v, A2v, C2v, J1v = (
        A1[...], C1[...], J2[...], A2[...], C2[...], J1[...])
    b1v, e1v, b2v, e2v = b1[...], e1[...], b2[...], e2[...]

    # M = I + C1 J2; Minv once, M^-T via index transpose (free).
    M = _matmat(C1v, J2v, nx)
    eye_rows = []
    for i in range(nx):
        eye_rows.append(jnp.stack(
            [M[i, j] + (1.0 if i == j else 0.0) for j in range(nx)], axis=0))
    M = jnp.stack(eye_rows, axis=0)
    Minv = _gauss_jordan_inverse(M, nx)
    MinvT = _transpose(Minv)

    MiA1 = _matmat(Minv, A1v, nx)
    oA[...] = _matmat(A2v, MiA1, nx)

    tmp = b1v + _matvec(C1v, e2v, nx)
    ob[...] = _matvec(A2v, _matvec(Minv, tmp, nx), nx) + b2v

    MiC1 = _matmat(Minv, C1v, nx)
    C12 = _matmat(A2v, _matmat(MiC1, _transpose(A2v), nx), nx) + C2v
    oC[...] = 0.5 * (C12 + _transpose(C12))

    w = e2v - _matvec(J2v, b1v, nx)
    oe[...] = _matvec(_transpose(A1v), _matvec(MinvT, w, nx), nx) + e1v

    MtJ2 = _matmat(MinvT, J2v, nx)
    J12 = _matmat(_transpose(A1v), _matmat(MtJ2, A1v, nx), nx) + J1v
    oJ[...] = 0.5 * (J12 + _transpose(J12))


def lqt_combine_lanes(ops1, ops2, *, block_b: int = 512,
                      interpret: bool = False):
    """Batched eq.-(42) combine in lane-major layout.

    ``ops1``/``ops2``: tuples (A, b, C, eta, J) with shapes
    (nx, nx, B) / (nx, B); B must be a multiple of ``block_b``.
    """
    A1, b1, C1, e1, J1 = ops1
    A2, b2, C2, e2, J2 = ops2
    nx, _, B = A1.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)

    mat_spec = pl.BlockSpec((nx, nx, block_b), lambda i: (0, 0, i))
    vec_spec = pl.BlockSpec((nx, block_b), lambda i: (0, i))
    specs = [mat_spec, vec_spec, mat_spec, vec_spec, mat_spec]

    out_shapes = (
        jax.ShapeDtypeStruct((nx, nx, B), A1.dtype),
        jax.ShapeDtypeStruct((nx, B), A1.dtype),
        jax.ShapeDtypeStruct((nx, nx, B), A1.dtype),
        jax.ShapeDtypeStruct((nx, B), A1.dtype),
        jax.ShapeDtypeStruct((nx, nx, B), A1.dtype),
    )
    return pl.pallas_call(
        functools.partial(_combine_kernel, nx=nx),
        grid=grid,
        in_specs=specs + specs,
        out_specs=tuple(specs),
        out_shape=out_shapes,
        # lane blocks are independent element batches -> parallel grid
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(A1, b1, C1, e1, J1, A2, b2, C2, e2, J2)
