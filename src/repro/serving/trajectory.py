"""Trajectory-estimation serving engine: MAP solves as a batched service.

``TrajectoryEngine`` is the estimation-workload sibling of
:class:`~repro.serving.engine.ServeEngine`: it serves
:class:`~repro.core.Problem` solves through one
:class:`~repro.core.Estimator`.  The production tricks:

* **fixed-batch padding** -- every wave is exactly ``batch`` rows, so each
  bucket length compiles ONE executable, reused forever (the executable
  cache lives in :mod:`repro.core.estimator`);
* **pad-and-bucket** -- ragged record lengths are padded to power-of-two
  block counts with masked measurements (exact, see
  :mod:`repro.core.padding`);
* **row recycling / continuous batching** -- short waves are topped up by
  recycling a live row, and the queue is drained in FIFO waves grouped by
  bucket so one submit/collect cycle serves any mix of lengths (the wave
  machinery is shared with :class:`~repro.serving.StreamingEngine`, see
  :mod:`repro.serving.waves`);
* **optional mesh sharding** -- pass a mesh (a ``jax.sharding.Mesh`` or
  a :class:`repro.distributed.MeshSpec`) and each wave is sharded over
  the mesh's batch axis, spreading requests across devices; with
  ``method="distributed"`` the mesh's time axis additionally shards the
  associative scan of every solve (2-D time x batch layout).

API: ``submit(ts, y) -> ticket``; ``step()`` solves one wave; ``collect()``
pops finished ``(ticket, Solution)`` pairs (``collect(tickets=...)``
pops only YOUR tickets -- concurrent collectors never steal each other's
results); ``estimate(records)`` is the synchronous convenience wrapper.

The solver configuration is the Estimator's: pass ``method=`` plus the
method's options dataclass (e.g. ``ParallelOptions(nsub=10,
mode="discrete")``, or ``IteratedOptions(...)`` for nonlinear models).
The pre-redesign kwargs (``nsub``/``mode``/``iterations``/
``divergence_correction``) are still accepted with a
``DeprecationWarning``.
"""
from __future__ import annotations

import collections
import threading
import time
import warnings
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core.estimator import Estimator, Problem, legacy_options
from repro.core.padding import bucket_length, slice_solution
from repro.core.sde import LinearSDE, NonlinearSDE
from repro.core.types import Solution

from .waves import (
    WaveItem,
    pack_wave,
    record_wave_metrics,
    robust_default_options,
    take_wave,
    validate_record,
)


class TrajectoryEngine:
    """Queued, batched MAP-estimation service for one model.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`.
      batch: fixed wave size (compiled batch).  With a mesh it must be
        divisible by the mesh's ``batch_axis`` size.
      method: registered method name; ``options`` its options dataclass
        -- both forwarded to the underlying :class:`~repro.core.Estimator`.
        ``options=None`` uses the method's defaults with the ``discrete``
        element mode (NOT the Estimator's paper-faithful ``euler``
        default, which can go NaN on long records -- see
        :func:`repro.serving.waves.robust_default_options`).
      bucket_sizes: optional explicit padded-length buckets (multiples of
        the method's block size); default is power-of-two block counts.
      mesh: optional ``jax.sharding.Mesh`` or
        :class:`repro.distributed.MeshSpec` (the unified mesh entry
        point) for batch-axis sharding; with ``method="distributed"``
        the mesh's time axis additionally shards the scan itself.

    ``submit``/``collect`` are thread-safe (one lock guards the queue and
    the finished map); ``step``/``run`` may be driven from a dedicated
    solver thread while clients submit and collect concurrently.
    """

    def __init__(
        self,
        model: Union[LinearSDE, NonlinearSDE],
        *,
        batch: int = 8,
        method: str = "parallel_rts",
        options=None,
        bucket_sizes: Optional[Sequence[int]] = None,
        mesh=None,
        batch_axis: str = "data",
        **legacy,
    ):
        if legacy:
            allowed = {"nsub", "mode", "iterations", "divergence_correction"}
            unknown = set(legacy) - allowed
            if unknown:
                raise TypeError(
                    f"unexpected keyword arguments: {sorted(unknown)}")
            if options is not None:
                raise TypeError(
                    "pass either options=... or the legacy kwargs "
                    f"{sorted(legacy)}, not both")
            warnings.warn(
                f"TrajectoryEngine kwargs {sorted(legacy)} are deprecated; "
                "pass the method's options dataclass via options= "
                "(see docs/MIGRATION.md)", DeprecationWarning, stacklevel=2)
            options = legacy_options(model, method, **legacy)
        elif options is None:
            # serving default: the robust exact-composition mode, NOT the
            # Estimator's paper-faithful euler default -- see
            # robust_default_options for the stability rationale.
            options = robust_default_options(method)
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.estimator = Estimator(model, method=method, options=options,
                                   mesh=mesh, batch_axis=batch_axis)
        shard = self.estimator._batch_shard_size(
            self.estimator._resolved_mesh())
        if batch % shard:
            raise ValueError(
                f"batch {batch} not divisible by mesh batch axis size "
                f"{shard}")
        self.model = model
        self.batch = batch
        self.bucket_sizes = bucket_sizes

        self._lock = threading.Lock()
        self._queue: Deque[WaveItem] = collections.deque()
        self._done: Dict[int, Solution] = {}
        self._next_ticket = 0
        self.waves = 0            # compiled-batch solves issued
        self.recycled_rows = 0    # padding rows recycled into short waves

    # -- submit / collect ---------------------------------------------------

    def submit(self, ts: np.ndarray, y: np.ndarray) -> int:
        """Enqueue one record; returns a ticket redeemable at collect().

        Validates shapes AND that ``ts`` is strictly increasing -- padding
        extrapolates the grid with the final step size, so a non-monotone
        grid would otherwise silently produce a broken padded problem.
        """
        ts, y = validate_record(ts, y)
        n_pad = bucket_length(y.shape[0], self.estimator.block_size,
                              self.bucket_sizes)
        with self._lock:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._queue.append(
                WaveItem(ticket, ts, y, n_pad, time.perf_counter()))
            depth = len(self._queue)
        if obs.enabled():
            obs.inc("engine.submitted")
            obs.set_gauge("engine.queue_depth", depth)
        return ticket

    def pending(self) -> int:
        return len(self._queue)

    def collect(
        self, tickets: Optional[Sequence[int]] = None,
    ) -> List[Tuple[int, Solution]]:
        """Pop finished ``(ticket, solution)`` pairs, ticket order.

        With ``tickets=None`` pops EVERY finished pair (single-consumer
        mode).  ``tickets=[...]`` pops only those tickets that are
        finished, leaving everything else for other collectors -- the
        multi-client form ``estimate()`` uses so concurrent callers never
        steal each other's results.  Tickets that are unknown, still
        pending, or already collected are simply not returned; use
        :meth:`describe_ticket` / ``estimate()`` for a diagnosis.
        """
        with self._lock:
            if tickets is None:
                out = sorted(self._done.items())
                self._done.clear()
            else:
                out = sorted((t, self._done.pop(t))
                             for t in set(tickets) if t in self._done)
        return out

    def describe_ticket(self, ticket: int) -> str:
        """Human-readable state of a ticket (for error messages)."""
        with self._lock:
            if ticket in self._done:
                return "finished (awaiting collect)"
            if any(item.key == ticket for item in self._queue):
                return "queued (not yet solved; call step()/run())"
            if 0 <= ticket < self._next_ticket:
                return "already collected (results are popped exactly once)"
            return f"never issued (tickets so far: 0..{self._next_ticket - 1})"

    # -- wave processing ----------------------------------------------------

    def step(self) -> int:
        """Solve one fixed-size wave; returns the number of requests
        completed (0 if the queue is empty).

        With ``repro.obs`` enabled each wave reports: occupancy (real
        rows / batch), padding waste (padded vs real intervals), queue
        depth, and per-record submit-to-done latency percentiles
        (``engine.record_latency_seconds``)."""
        with self._lock:
            if not self._queue:
                return 0
            wave = take_wave(self._queue, self.batch)
            depth = len(self._queue)
        with obs.trace_span("engine.step"):
            n_pad = wave[0].n_pad
            ts_b, ys_b, mask_b, _, _ = pack_wave(wave, self.batch)
            sol = self.estimator.solve(
                Problem.stacked(self.model, ts_b, ys_b,
                                measurement_mask=mask_b))
            done = {item.key: slice_solution(sol, row, item.y.shape[0])
                    for row, item in enumerate(wave)}
            with self._lock:
                self._done.update(done)
                self.waves += 1
                self.recycled_rows += self.batch - len(wave)
            if obs.enabled():
                record_wave_metrics("engine", wave, n_pad, self.batch, depth)
        return len(wave)

    def run(self) -> int:
        """Drain the queue; returns the total number of requests solved.

        With ``repro.obs`` enabled, sets ``engine.tracks_per_sec`` (drain
        throughput of this call)."""
        total = 0
        t0 = time.perf_counter()
        with obs.trace_span("engine.run"):
            while self._queue:
                total += self.step()
        dt = time.perf_counter() - t0
        if total and dt > 0:
            obs.set_gauge("engine.tracks_per_sec", total / dt)
        return total

    # -- synchronous convenience --------------------------------------------

    def estimate(
        self, records: Sequence[Tuple[np.ndarray, np.ndarray]],
    ) -> List[Solution]:
        """Submit ``(ts, y)`` records, drain, return solutions in order.

        Collects ONLY its own tickets (``collect(tickets=...)``), so
        concurrent ``collect()`` / ``estimate()`` callers cannot steal
        these results.  If a ticket still cannot be redeemed the error
        says why (queued / already collected / never issued) instead of a
        bare ``KeyError``.
        """
        tickets = [self.submit(ts, y) for ts, y in records]
        self.run()
        got = dict(self.collect(tickets=tickets))
        missing = [t for t in tickets if t not in got]
        if missing:
            states = ", ".join(
                f"ticket {t}: {self.describe_ticket(t)}" for t in missing)
            raise KeyError(
                f"estimate() could not redeem {len(missing)} ticket(s) -- "
                f"{states}")
        return [got[t] for t in tickets]
