"""Pallas TPU kernels for the performance hot spots.

Each kernel ships three layers: ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (jitted wrapper + training-path VJP), ``ref.py``
(pure-jnp oracle used by the allclose test sweeps).  On the CPU container
the kernels run under ``interpret=True``; TPU is the deployment target.
"""
from . import flash_attention, lqt_combine, ssd

__all__ = ["flash_attention", "lqt_combine", "ssd"]
