"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the exact smollm-135m architecture (135M params) on the synthetic
noisy-copy corpus, with checkpointing/auto-resume enabled -- kill and
rerun the script and it continues from the last checkpoint.

CPU-sized defaults (seq 256, batch 4) keep a step under a few seconds;
on a TPU mesh the same driver scales via the sharding rules (see
launch/train.py for the CLI version).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax

from repro.config import TrainConfig, get_config
from repro.train.data import LMDataPipeline
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")          # the real 135M config
    tcfg = TrainConfig(
        learning_rate=6e-4, warmup_steps=20, total_steps=args.steps,
        seq_len=args.seq, global_batch=args.batch,
        checkpoint_every=50, keep_checkpoints=2, log_every=10)
    pipe = LMDataPipeline(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0, period=64, corruption=0.1)
    print(f"[example] {cfg.name}: {cfg.param_count():,} params, "
          f"{jax.device_count()} device(s)")
    trainer = Trainer(cfg=cfg, tcfg=tcfg, pipeline=pipe,
                      ckpt_dir=args.ckpt_dir)
    _, _, metrics = trainer.run(args.steps)
    print(f"[example] final loss {float(metrics['loss']):.4f} "
          f"(uniform floor ~{jax.numpy.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
