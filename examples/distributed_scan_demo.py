"""Temporal parallelism across devices: the paper's scan, sharded in time.

Forces 8 host devices, shards a T=512-block Kalman-Bucy element sequence
over them, and runs the distributed suffix scan (local Blelloch scan +
one all-gather of carries + local fix-up) -- the multi-pod decomposition
of DESIGN.md S3.  Verifies exact agreement with the single-device scan.

    PYTHONPATH=src python examples/distributed_scan_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

from functools import partial

import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.wiener_velocity import WienerVelocityConfig
from repro.core import (
    distributed_scan, grid_lqt_from_linear, lqt_combine, simulate_linear,
    suffix_scan, time_grid,
)
from repro.core.elements import discrete_block_elements, terminal_element
from repro.core.types import LQTElement

cfg = WienerVelocityConfig(p0=1.0)
model = cfg.model()
T, n = 512, 10
ts = time_grid(cfg.t0, cfg.tf, T * n)
_, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
grid = grid_lqt_from_linear(model, ts, y)

blocks, _ = discrete_block_elements(grid, n)
# fold the prior element into the last block so T stays device-divisible
last = jax.tree_util.tree_map(lambda a: a[-1], blocks)
folded = lqt_combine(last, terminal_element(grid))
elems = jax.tree_util.tree_map(
    lambda a, f: jnp.concatenate([a[:-1], f[None]], axis=0), blocks, folded)

mesh = jax.make_mesh((8,), ("time",))
spec = LQTElement(*(P("time"),) * 5)
dist = jax.jit(shard_map(
    partial(distributed_scan, lqt_combine, axis_name="time", reverse=True),
    mesh=mesh, in_specs=(spec,), out_specs=spec))

got = dist(elems)
want = suffix_scan(lqt_combine, elems)
gap = max(float(jnp.abs(a - b).max()) for a, b in zip(got, want))
print(f"devices           : {jax.device_count()}")
print(f"time blocks       : {T} ({T // 8} per device)")
print(f"distributed vs single-device scan max gap: {gap:.2e}")
print("filter info at t_f (diag):", jnp.diagonal(got.J[0]).round(3))
assert gap < 1e-8
print("OK")
