"""Jitted wrapper around the chunked-SSD Pallas kernel.

Handles layout (batch/head flattening, group -> head broadcast), padding of
the sequence to the chunk size, the D skip connection, and the differential
path: the kernel carries a ``jax.custom_vjp`` whose backward pass uses the
reference implementation's VJP (forward speed is the production concern;
training on TPU can swap in a dedicated backward kernel without touching
callers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunked
from .ref import ssd_ref


def _prep(x, dt, A, B, C):
    b, L, H, P = x.shape
    G, S = B.shape[2], B.shape[3]
    rep = H // G
    l = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(b * H, L)
    dtx = (dt[..., None] * x).transpose(0, 2, 1, 3).reshape(b * H, L, P)
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * H, L, S)
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * H, L, S)
    return l, dtx, Bh, Ch


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, D=None, *, chunk: int = 128,
        interpret: bool = False):
    """Chunked SSD forward (see ref.ssd_ref for the semantics)."""
    b, L, H, P = x.shape
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l, dtx, Bh, Ch = _prep(x, dt, A, B, C)
    y = ssd_chunked(l, dtx, Bh, Ch, chunk=chunk, interpret=interpret)
    y = y.reshape(b, H, L + pad, P).transpose(0, 2, 1, 3)[:, :L]
    if D is not None:
        y = y + D[None, None, :, None] * x[:, :L]
    return y


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd_trainable(x, dt, A, B, C, D, chunk=128, interpret=False):
    return ssd(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)


def _fwd(x, dt, A, B, C, D, chunk, interpret):
    y = ssd_trainable(x, dt, A, B, C, D, chunk, interpret)
    return y, (x, dt, A, B, C, D)


def _bwd(chunk, interpret, res, g):
    x, dt, A, B, C, D = res
    _, vjp = jax.vjp(lambda *a: ssd_ref(*a), x, dt, A, B, C, D)
    return vjp(g)


ssd_trainable.defvjp(_fwd, _bwd)
