"""Fault-tolerant checkpointing: atomic msgpack snapshots, keep-last-k,
auto-resume, elastic resharding.

Format: one ``step_<N>.ckpt`` msgpack file holding the flattened pytree
(dtype/shape/raw bytes per leaf) plus a treedef fingerprint, written to a
temp file and atomically renamed -- a crash mid-write can never corrupt the
latest checkpoint.  Arrays are saved UNSHARDED-LOGICAL (fully addressable
host values), so a restore may target a different mesh shape: the restored
arrays are ``device_put`` against whatever NamedShardings the new mesh
produces (elastic scaling across restarts; DESIGN.md S5).

On SIGTERM (preemption notice) the trainer requests a final checkpoint via
``CheckpointManager.request_save()`` -- see train/trainer.py.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    # dtype NAME (not .str): ml_dtypes types like bfloat16 stringify to
    # opaque void descriptors ('|V2') that cannot round-trip
    return {b"dtype": arr.dtype.name.encode(),
            b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _unpack_leaf(d: dict) -> np.ndarray:
    dtype = _resolve_dtype(d[b"dtype"].decode())
    arr = np.frombuffer(d[b"data"], dtype=dtype)
    return arr.reshape(d[b"shape"])


def save_checkpoint(path: str, step: int, tree: Any) -> str:
    """Atomically write ``tree`` to ``<path>/step_<step>.ckpt``."""
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        b"step": step,
        b"treedef": str(treedef).encode(),
        b"leaves": [_pack_leaf(l) for l in leaves],
    }
    final = os.path.join(path, f"step_{step:012d}.ckpt")
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)          # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return final


def latest_checkpoint(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        m = re.fullmatch(r"step_(\d+)\.ckpt", name)
        if m:
            steps.append((int(m.group(1)), name))
    if not steps:
        return None
    steps.sort()
    return os.path.join(path, steps[-1][1])


def restore_checkpoint(file: str, like: Any, shardings: Any = None):
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (same tree structure) when given -- works across mesh-shape changes."""
    with open(file, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves_np = [_unpack_leaf(d) for d in payload[b"leaves"]]
    _, treedef = jax.tree_util.tree_flatten(like)
    if str(treedef).encode() != payload[b"treedef"]:
        raise ValueError(
            "checkpoint treedef mismatch -- incompatible model/opt config")
    tree = jax.tree_util.tree_unflatten(treedef, leaves_np)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return payload[b"step"], tree


def prune_checkpoints(path: str, keep: int) -> None:
    if not os.path.isdir(path):
        return
    files = sorted(
        f for f in os.listdir(path)
        if re.fullmatch(r"step_\d+\.ckpt", f))
    for f in files[:-keep] if keep > 0 else []:
        os.unlink(os.path.join(path, f))
