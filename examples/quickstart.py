"""Quickstart: parallel-in-time MAP trajectory estimation in ~30 lines.

Simulates the paper's Wiener velocity model (section 5.1), runs the
parallel continuous-time RTS smoother through the unified
``Estimator``/``Problem`` surface, and compares it against the sequential
baseline and the ground truth.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.configs.wiener_velocity import WienerVelocityConfig
from repro.core import (
    Estimator, ParallelOptions, Problem, SequentialOptions, simulate_linear,
    time_grid,
)

cfg = WienerVelocityConfig(p0=1.0)      # see DESIGN.md S6 on the prior
model = cfg.model()

T, n = 256, 10                           # T scan blocks x n Euler substeps
ts = time_grid(cfg.t0, cfg.tf, T * n)
x_true, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
problem = Problem.single(model, ts, y)

# "discrete" composes exact substep elements -> parallel == sequential to
# round-off; "euler" is the paper's literal ODE mode (O(dt) agreement).
par = Estimator(model, method="parallel_rts",
                options=ParallelOptions(nsub=n, mode="discrete"))
seq = Estimator(model, method="sequential_rts",
                options=SequentialOptions(mode="discrete"))
sol_par = par.solve(problem)
sol_seq = seq.solve(problem)

rmse = jnp.sqrt(jnp.mean((sol_par.x[:, :2] - x_true[:, :2]) ** 2))
gap = jnp.abs(sol_par.x - sol_seq.x).max()

print(f"trajectory points : {sol_par.x.shape[0]}")
print(f"position RMSE     : {float(rmse):.4f}")
print(f"Onsager-Machlup cost of the MAP estimate: {float(sol_par.cost):.2f}")
print(f"parallel vs sequential max gap: {float(gap):.2e}")
print("filter information S(t_f) diag:",
      jnp.diagonal(sol_par.S[-1]).round(2))
assert float(gap) < 1e-8
assert float(jnp.abs(sol_par.cost - sol_seq.cost)) < 1e-6
print("OK")
