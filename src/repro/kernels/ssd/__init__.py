from .ops import ssd, ssd_trainable
from .ref import ssd_ref
