"""Method registry: one dispatch table for every MAP solver backend.

Each entry is a :class:`MethodSpec` pairing the solver callable with the
:class:`~repro.core.options.SolverOptions` dataclass it owns, so
method-specific knobs (``nsub``, ``block0_fill``, ...) live with the
solver instead of widening every public signature.  New backends (e.g. a
kernel-backed combine, a distributed-scan variant) plug in with
:func:`register_method` without touching any call site:

    registry.register_method("my_method", solver, MyOptions)

where ``solver(grid: GridLQT, options: MyOptions) -> MAPSolution``.  The
legacy ``solver(grid, nsub, mode)`` signature (pre-options registrations)
is still accepted when ``options_cls`` is omitted; it is adapted to the
canonical form and assigned :class:`~repro.core.options.ParallelOptions`.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple, Type

from .options import (
    DistributedOptions,
    IteratedOptions,
    KernelOptions,
    ParallelOptions,
    SequentialOptions,
    SigmaPointOptions,
    SolverOptions,
    TwoFilterOptions,
)
from .parallel import parallel_rts, parallel_two_filter
from .sequential import sequential_rts, sequential_two_filter
from .types import GridLQT, MAPSolution

Solver = Callable[[GridLQT, SolverOptions], MAPSolution]


class MethodSpec(NamedTuple):
    """A registered solver backend: name + canonical solver + its options."""

    name: str
    solver: Solver
    options_cls: Type[SolverOptions]

    def default_options(self) -> SolverOptions:
        return self.options_cls()

    @property
    def nonlinear(self) -> bool:
        """True for methods whose options are the iterated-linearisation
        layer (``IteratedOptions`` subclasses): they require a nonlinear
        model and delegate each linearised subproblem to an inner linear
        method instead of acting as a grid solver themselves."""
        return issubclass(self.options_cls, IteratedOptions)


_METHODS: Dict[str, MethodSpec] = {}


def register_method(
    name: str,
    solver: Callable,
    options_cls: Optional[Type[SolverOptions]] = None,
    *,
    overwrite: bool = False,
) -> None:
    """Register a solver backend under ``name``.

    ``solver`` must accept ``(grid, options)`` -- with ``options`` an
    instance of ``options_cls`` -- and return a
    :class:`~repro.core.types.MAPSolution`.  Omitting ``options_cls``
    registers a legacy ``(grid, nsub, mode)`` solver, adapted in place.
    """
    if options_cls is None:
        legacy = solver

        def solver(grid, options, _legacy=legacy):  # noqa: F811
            return _legacy(grid, getattr(options, "nsub", 1), options.mode)

        options_cls = ParallelOptions
    elif not (isinstance(options_cls, type)
              and issubclass(options_cls, (SolverOptions, IteratedOptions))):
        raise TypeError(
            f"options_cls must be a SolverOptions subclass (or an "
            f"IteratedOptions subclass for nonlinear methods), got "
            f"{options_cls!r}")
    if name in _METHODS and not overwrite:
        raise ValueError(f"method {name!r} already registered")
    _METHODS[name] = MethodSpec(name, solver, options_cls)


def get_method(name: str) -> MethodSpec:
    try:
        return _METHODS[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {method_names()}, got {name!r}"
        ) from None


def get_solver(name: str) -> Callable:
    """Back-compat accessor: a ``(grid, nsub, mode)`` adapter around the
    registered solver (fields the method's options do not declare are
    dropped)."""
    spec = get_method(name)

    def solver(grid, nsub, mode):
        return spec.solver(grid,
                           spec.options_cls.from_legacy(nsub=nsub, mode=mode))

    return solver


def method_names() -> Tuple[str, ...]:
    return tuple(_METHODS)


def _parallel_kernel_solver(grid: GridLQT, o: KernelOptions) -> MAPSolution:
    """RTS smoother with the backward scan run by the Pallas lane-major
    combine kernel (one layout round-trip for the whole multi-level scan).

    The kernel package is imported lazily so ``repro.core`` never depends
    on ``repro.kernels`` at import time (the kernels import core types).
    """
    from repro.kernels.lqt_combine.ops import kernel_suffix_scan

    interpret = o.resolve_interpret()

    def suffix(elems):
        return kernel_suffix_scan(elems, block_b=o.block_size,
                                  interpret=interpret, precision=o.precision)

    return parallel_rts(grid, o.nsub, o.mode, suffix_scan_fn=suffix)


def _distributed_solver(grid: GridLQT, o: DistributedOptions) -> MAPSolution:
    """RTS smoother with both global scans sharded over a named time axis
    (:func:`repro.core.pscan.sharded_scan`): local Blelloch scan per shard,
    one all-gather of the P per-shard carries, redundant carry scan, local
    fix-up -- span O(log(T/P) + P).

    The mesh is resolved at TRACE time: an explicit/ambient mesh carrying
    ``options.time_axis`` (see :func:`repro.distributed.resolve_time_mesh`)
    wins, else a default time-only mesh over ``devices_per_time`` (or all
    visible) devices is built.  With fewer than 2 time shards the solver
    degrades to the single-device parallel scan (``fallback="auto"``) or
    raises (``fallback="error"``).
    """
    from repro.distributed.sharding import resolve_time_mesh

    from . import pscan
    from .combine import affine_combine, lqt_combine

    mesh = resolve_time_mesh(
        o.time_axis, devices_per_time=o.devices_per_time)
    if mesh is None:
        if o.fallback == "error":
            raise RuntimeError(
                f"method='distributed' needs >= 2 devices on mesh axis "
                f"{o.time_axis!r} (fallback='error'); pass "
                f"fallback='auto' to degrade to the single-device scan")
        return parallel_rts(grid, o.nsub, o.mode)

    carry_dtype = o.resolve_carry_dtype()

    def suffix(elems):
        return pscan.sharded_scan(
            lqt_combine, elems, mesh=mesh, axis_name=o.time_axis,
            reverse=True, carry_dtype=carry_dtype)

    def prefix(elems):
        return pscan.sharded_scan(
            affine_combine, elems, mesh=mesh, axis_name=o.time_axis,
            carry_dtype=carry_dtype)

    return parallel_rts(grid, o.nsub, o.mode,
                        suffix_scan_fn=suffix, prefix_scan_fn=prefix)


register_method(
    "parallel_rts",
    lambda grid, o: parallel_rts(grid, o.nsub, o.mode),
    ParallelOptions)
register_method("parallel_kernel", _parallel_kernel_solver, KernelOptions)
register_method("distributed", _distributed_solver, DistributedOptions)
register_method(
    "parallel_two_filter",
    lambda grid, o: parallel_two_filter(
        grid, o.nsub, o.mode, jitter=o.jitter,
        block0_fill=o.block0_fill, tf_fill=o.tf_fill),
    TwoFilterOptions)
def _sigma_point_solver(grid: GridLQT, o: SigmaPointOptions) -> MAPSolution:
    """``sigma_point`` is not a grid solver: the Estimator resolves its
    ``inner_method`` and runs the iterated loop around THAT solver.  Only
    a direct ``spec.solver(grid, options)`` call -- which would silently
    skip the linearisation loop -- lands here."""
    raise TypeError(
        "method='sigma_point' is an iterated nonlinear method, not a grid "
        "solver; use Estimator(model, method='sigma_point').solve(problem) "
        "with a NonlinearSDE model")


register_method("sigma_point", _sigma_point_solver, SigmaPointOptions)
register_method(
    "sequential_rts",
    lambda grid, o: sequential_rts(grid, o.mode),
    SequentialOptions)
register_method(
    "sequential_two_filter",
    lambda grid, o: sequential_two_filter(grid, o.mode),
    SequentialOptions)
