"""Pure-jnp oracle for the batched LQT combination (paper eq. 42).

This is the same math as :func:`repro.core.combine.lqt_combine`, exposed in
the kernel's batched-array calling convention: five (B, nx, nx)/(B, nx)
arrays per operand side.
"""
from __future__ import annotations

from repro.core.combine import lqt_combine as _core_combine
from repro.core.pscan import prefix_scan, suffix_scan
from repro.core.types import LQTElement


def lqt_combine_ref(A1, b1, C1, eta1, J1, A2, b2, C2, eta2, J2):
    out = _core_combine(
        LQTElement(A1, b1, C1, eta1, J1), LQTElement(A2, b2, C2, eta2, J2))
    return tuple(out)


def lqt_scan_ref(elems: LQTElement, *, reverse: bool = False) -> LQTElement:
    """Pure-jnp scan oracle for the whole-scan kernel path
    (:func:`repro.kernels.lqt_combine.ops.kernel_prefix_scan` /
    ``kernel_suffix_scan``): the core associative scan with the core
    combine, in the element-major (scan axis 0) layout."""
    scan = suffix_scan if reverse else prefix_scan
    return scan(_core_combine, elems)
