"""Batched multi-trajectory estimation through the unified surface:
stacked == looped single solves (linear + nonlinear), exact
length-padding, ragged bucketing + padding report, and the jit-executable
cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import coordinated_turn, wiener_velocity
from repro.core import (
    Estimator,
    IteratedOptions,
    Problem,
    bucket_length,
    cache_stats,
    get_method,
    pad_record,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)

NSUB = 5


def _options(method, **kw):
    return get_method(method).options_cls.from_legacy(**kw)


def _linear_batch(B=3, T=4, seed=0):
    model = wiener_velocity()
    ts = time_grid(0.0, 1.0, T * NSUB)
    ys = jnp.stack([simulate_linear(model, ts, jax.random.PRNGKey(seed + i))[1]
                    for i in range(B)])
    return model, ts, ys


def _nonlinear_batch(B=3, T=4, seed=10):
    model = coordinated_turn()
    ts = time_grid(0.0, 1.0, T * NSUB)
    ys = jnp.stack(
        [simulate_nonlinear(model, ts, jax.random.PRNGKey(seed + i))[1]
         for i in range(B)])
    return model, ts, ys


@pytest.mark.parametrize("method", ["parallel_rts", "sequential_rts"])
def test_linear_stacked_matches_loop(method):
    model, ts, ys = _linear_batch()
    est = Estimator(model, method=method,
                    options=_options(method, nsub=NSUB, mode="discrete"))
    sol = est.solve(Problem.stacked(model, ts, ys))
    assert sol.x.shape == (ys.shape[0], ys.shape[1] + 1, model.nx)
    assert sol.cost.shape == (ys.shape[0],)
    for i in range(ys.shape[0]):
        ref = est.solve(Problem.single(model, ts, ys[i]))
        np.testing.assert_allclose(sol.x[i], ref.x, atol=1e-6, rtol=0)
        np.testing.assert_allclose(sol.S[i], ref.S, atol=1e-6, rtol=0)
        np.testing.assert_allclose(sol.cost[i], ref.cost, atol=1e-6, rtol=0)


@pytest.mark.parametrize("method", ["parallel_rts", "sequential_rts"])
def test_nonlinear_stacked_matches_loop(method):
    model, ts, ys = _nonlinear_batch()
    est = Estimator(
        model, method=method,
        options=IteratedOptions(
            iterations=3, inner=_options(method, nsub=NSUB, mode="euler")))
    sol = est.solve(Problem.stacked(model, ts, ys))
    assert sol.cost_trace.shape == (ys.shape[0], 3)
    for i in range(ys.shape[0]):
        ref = est.solve(Problem.single(model, ts, ys[i]))
        np.testing.assert_allclose(sol.x[i], ref.x, atol=1e-6, rtol=0)
        np.testing.assert_allclose(sol.cost_trace[i], ref.cost_trace,
                                   atol=1e-6, rtol=0)


def test_stacked_per_record_time_grids():
    """ts may be (B, N+1): records sharing N but not the grid itself."""
    model = wiener_velocity()
    N = 4 * NSUB
    ts_b = jnp.stack([time_grid(0.0, 1.0 + 0.5 * i, N) for i in range(2)])
    ys = jnp.stack([simulate_linear(model, ts_b[i],
                                    jax.random.PRNGKey(20 + i))[1]
                    for i in range(2)])
    est = Estimator(model, method="parallel_rts",
                    options=_options("parallel_rts", nsub=NSUB,
                                     mode="discrete"))
    sol = est.solve(Problem.stacked(model, ts_b, ys))
    for i in range(2):
        ref = est.solve(Problem.single(model, ts_b[i], ys[i]))
        np.testing.assert_allclose(sol.x[i], ref.x, atol=1e-8, rtol=0)


def test_masked_padding_is_exact():
    """A masked tail beyond t_f must leave the real window unchanged."""
    model, ts, ys = _linear_batch(B=1)
    N = ys.shape[1]
    ts_p, y_p, mask = pad_record(np.asarray(ts), np.asarray(ys[0]),
                                 N + 3 * NSUB)
    est = Estimator(model, method="parallel_rts",
                    options=_options("parallel_rts", nsub=NSUB,
                                     mode="discrete"))
    ref = est.solve(Problem.single(model, ts, ys[0]))
    sol = est.solve(Problem.single(
        model, jnp.asarray(ts_p), jnp.asarray(y_p),
        measurement_mask=jnp.asarray(mask)))
    np.testing.assert_allclose(sol.x[:N + 1], ref.x, atol=1e-9, rtol=0)
    np.testing.assert_allclose(sol.S[:N + 1], ref.S, atol=1e-9, rtol=0)


def test_bucket_length_rules():
    assert bucket_length(1, 5) == 5
    assert bucket_length(5, 5) == 5
    assert bucket_length(6, 5) == 10
    assert bucket_length(11, 5) == 20
    assert bucket_length(95, 10) == 160
    assert bucket_length(7, 5, bucket_sizes=[10, 40]) == 10
    assert bucket_length(11, 5, bucket_sizes=[10, 40]) == 40
    with pytest.raises(ValueError):
        bucket_length(50, 5, bucket_sizes=[10, 40])
    with pytest.raises(ValueError):
        bucket_length(7, 5, bucket_sizes=[12])   # not a multiple of nsub


def test_pad_record_shapes_and_grid():
    ts = np.linspace(0.0, 1.0, 11)
    y = np.ones((10, 2))
    ts_p, y_p, mask = pad_record(ts, y, 15)
    assert ts_p.shape == (16,) and y_p.shape == (15, 2)
    np.testing.assert_allclose(np.diff(ts_p), 0.1, atol=1e-12)
    assert mask.tolist() == [1.0] * 10 + [0.0] * 5


def test_ragged_matches_individual_solves():
    model = wiener_velocity()
    lengths = [12, 20, 35]          # buckets: 20, 20, 40 (nsub=5)
    records = []
    for i, N in enumerate(lengths):
        ts_i = time_grid(0.0, N / 20.0, N)
        _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(30 + i))
        records.append((np.asarray(ts_i), np.asarray(y_i)))
    est = Estimator(model, method="parallel_rts",
                    options=_options("parallel_rts", nsub=NSUB,
                                     mode="discrete"))
    sols = est.solve(Problem.ragged(model, records))
    assert [s.x.shape[0] for s in sols] == [n + 1 for n in lengths]
    seq = Estimator(model, method="sequential_rts",
                    options=_options("sequential_rts", mode="discrete"))
    for (ts_i, y_i), sol in zip(records, sols):
        # reference: the nsub-free sequential solver on the UNPADDED record
        # (12 and 35 are not multiples of nsub -- only bucketing makes them
        # parallel-solvable); discrete mode is exact, so agreement is tight.
        ref = seq.solve(Problem.single(model, jnp.asarray(ts_i),
                                       jnp.asarray(y_i)))
        np.testing.assert_allclose(sol.x, ref.x, atol=1e-6, rtol=0)


def test_ragged_padding_report():
    model = wiener_velocity()
    lengths = [12, 20, 35]          # buckets: 20 (x2 records), 40 (x1)
    records = []
    for i, N in enumerate(lengths):
        ts_i = time_grid(0.0, N / 20.0, N)
        _, y_i = simulate_linear(model, ts_i, jax.random.PRNGKey(70 + i))
        records.append((np.asarray(ts_i), np.asarray(y_i)))
    est = Estimator(model, method="parallel_rts",
                    options=_options("parallel_rts", nsub=NSUB,
                                     mode="discrete"))
    sols = est.solve(Problem.ragged(model, records))
    report = sols[0].padding
    assert all(s.padding is report for s in sols)
    assert report.lengths == (12, 20, 35)
    assert [(b.n_pad, b.records, b.batch) for b in report.buckets] == [
        (20, 2, 2), (40, 1, 1)]
    assert report.records == 3
    assert report.real_intervals == 67
    assert report.solved_intervals == 2 * 20 + 40
    assert 0.0 < report.interval_utilisation <= 1.0
    assert report.row_utilisation == 1.0
    # bucket_sizes override routes every record into one bucket
    sols2 = est.solve(Problem.ragged(model, records, bucket_sizes=[40]))
    assert [(b.n_pad, b.records) for b in sols2[0].padding.buckets] == [
        (40, 3)]
    for a, b in zip(sols, sols2):
        np.testing.assert_allclose(a.x, b.x, atol=1e-6, rtol=0)


def test_executable_cache_reuse():
    model, ts, ys = _linear_batch(B=2, seed=40)
    est = Estimator(model, method="parallel_rts",
                    options=_options("parallel_rts", nsub=NSUB,
                                     mode="discrete"))
    est.solve(Problem.stacked(model, ts, ys))
    before = cache_stats()
    est.solve(Problem.stacked(model, ts, ys * 2.0))   # same shapes
    after = cache_stats()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    # a new shape compiles a new executable ...
    est.solve(Problem.stacked(model, ts, ys[:1]))
    assert cache_stats()["misses"] == before["misses"] + 1
    # ... and a second Estimator with EQUAL options reuses the first's
    # executable (the cache is shared and keyed by value, not instance).
    est2 = Estimator(model, method="parallel_rts",
                     options=_options("parallel_rts", nsub=NSUB,
                                      mode="discrete"))
    est2.solve(Problem.stacked(model, ts, ys))
    assert cache_stats()["misses"] == before["misses"] + 1


def test_stacked_input_validation():
    model, ts, ys = _linear_batch(B=2, seed=50)
    with pytest.raises(ValueError):
        Problem.stacked(model, ts, ys[0])            # missing batch axis
    with pytest.raises(ValueError):
        Problem.stacked(model, ts[:-1], ys)          # N mismatch
    with pytest.raises(ValueError):
        Problem.stacked(model, ts, ys,
                        measurement_mask=jnp.ones((2, 3)))
    with pytest.raises(ValueError):
        Estimator(model, method="no_such_method")
