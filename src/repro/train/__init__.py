from . import checkpoint, data, optimizer, trainer
from .optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule
from .trainer import Trainer, make_shardings, make_train_step
