"""Jitted wrappers for the LQT-combine Pallas kernel.

``lqt_combine_batched`` takes the natural (B, nx, nx)/(B, nx) layout,
re-lays out to the kernel's lane-major form (batch minor), pads B to the
block size, runs the kernel and restores the layout.  When the whole scan
runs kernel-side, keep the lane-major layout across levels instead --
``kernel_prefix_scan`` / ``kernel_suffix_scan`` below do exactly that:
ONE ``_to_lanes``/``_from_lanes`` round-trip total, with every scan level
slicing/combining lane-major operands in place.  The multi-level tree is
the same work-efficient recursion as ``jax.lax.associative_scan``, so the
combine ORDER matches the jnp scan; the per-combine arithmetic still
differs (unpivoted Gauss-Jordan vs pivoted ``linalg.solve``), so results
agree to tolerance, not bit-exactly.

On non-TPU backends (this container) ``interpret=True`` executes the kernel
body with the Pallas interpreter -- bit-accurate semantics, no Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.types import LQTElement

from .kernel import lqt_combine_lanes


def _to_lanes(e: LQTElement):
    return (
        jnp.transpose(e.A, (1, 2, 0)),
        jnp.transpose(e.b, (1, 0)),
        jnp.transpose(e.C, (1, 2, 0)),
        jnp.transpose(e.eta, (1, 0)),
        jnp.transpose(e.J, (1, 2, 0)),
    )


def _from_lanes(ops) -> LQTElement:
    A, b, C, eta, J = ops
    return LQTElement(
        jnp.transpose(A, (2, 0, 1)), jnp.transpose(b, (1, 0)),
        jnp.transpose(C, (2, 0, 1)), jnp.transpose(eta, (1, 0)),
        jnp.transpose(J, (2, 0, 1)))


def _pad_lanes(ops, pad):
    if pad == 0:
        return ops
    out = []
    for a in ops:
        width = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
        out.append(jnp.pad(a, width))
    return tuple(out)


def _combine_lanes(ops1, ops2, *, block_b: int, interpret: bool):
    """Kernel combine on lane-major 5-tuples of ANY lane count.

    Pads both operand tuples to a ``block_b`` multiple (zero lanes are
    garbage-free: C1 J2 = 0 so the Gauss-Jordan pivots stay 1) and slices
    the pad back off.  ``B == 0`` (empty tree levels) short-circuits.

    Obs: each call increments the ``kernel.lqt_combine.*`` launch
    counters.  These run at TRACE time (shapes are static ints, no tracer
    is captured), so they count kernel call sites emitted into the
    compiled program -- i.e. launches per execution of one compiled scan;
    cached executables do not re-count on reuse.
    """
    B = ops1[0].shape[-1]
    if B == 0:
        return ops1
    bb = min(block_b, max(8, B))
    pad = (-B) % bb
    if obs.enabled():
        obs.inc("kernel.lqt_combine.launches")
        obs.inc("kernel.lqt_combine.lanes", B)
        obs.inc("kernel.lqt_combine.pad_lanes", pad)
    out = lqt_combine_lanes(_pad_lanes(ops1, pad), _pad_lanes(ops2, pad),
                            block_b=bb, interpret=interpret)
    return tuple(a[..., :B] for a in out)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lqt_combine_batched(e1: LQTElement, e2: LQTElement, *,
                        block_b: int = 512,
                        interpret: bool = False) -> LQTElement:
    """Kernel-backed eq. (42) combine on (B, nx, nx)-layout elements."""
    if e1.A.shape[0] == 0:  # associative_scan emits empty tree levels
        return e1
    return _from_lanes(_combine_lanes(_to_lanes(e1), _to_lanes(e2),
                                      block_b=block_b, interpret=interpret))


# ---------------------------------------------------------------------------
# Whole-scan kernel path: multi-level associative scan in lane-major layout
# ---------------------------------------------------------------------------


def _interleave_lanes(even, odd):
    """Riffle two lane-major arrays: out[..., 0::2] = even, [1::2] = odd."""
    n = even.shape[-1] + odd.shape[-1]
    out = jnp.zeros(even.shape[:-1] + (n,), even.dtype)
    return out.at[..., 0::2].set(even).at[..., 1::2].set(odd)


def _scan_lanes(ops, combine):
    """Inclusive prefix scan over the LANE (last) axis, earlier operand
    first -- the recursive pair-reduce/odd-scan/even-fixup tree of
    ``jax.lax.associative_scan``, expressed on lane-major tuples so each
    level is one (or two) kernel combines over lane slices."""
    n = ops[0].shape[-1]
    if n < 2:
        return ops
    evens = tuple(a[..., 0:-1:2] for a in ops)          # lanes 0, 2, ...
    odds = tuple(a[..., 1::2] for a in ops)             # lanes 1, 3, ...
    odd_scanned = _scan_lanes(combine(evens, odds), combine)
    even_in = tuple(a[..., 2::2] for a in ops)          # lanes 2, 4, ...
    left = odd_scanned if n % 2 else tuple(a[..., :-1] for a in odd_scanned)
    even_scanned = combine(left, even_in)
    even_out = tuple(
        jnp.concatenate([a[..., :1], e], axis=-1)
        for a, e in zip(ops, even_scanned))
    return tuple(map(_interleave_lanes, even_out, odd_scanned))


def _scan_dtype(precision: str, dtype):
    if precision in (None, "default"):
        return dtype
    if precision == "float64" and not jax.config.jax_enable_x64:
        # astype would silently canonicalise the cast down to float32
        raise ValueError(
            "precision='float64' requires jax_enable_x64 (the cast would "
            "silently truncate to float32 under the default JAX config)")
    return jnp.dtype(precision)


def kernel_prefix_scan(elems: LQTElement, *, block_b: int = 512,
                       interpret: bool = False,
                       precision: str = "default") -> LQTElement:
    """Inclusive prefix combine along axis 0 (earlier operand first), run
    kernel-side in lane-major layout with one layout round-trip total.

    ``precision`` selects the kernel compute dtype (``"default"`` keeps the
    element dtype; ``"float32"``/``"float64"`` cast for the scan and cast
    the result back).
    """
    lanes = _to_lanes(elems)
    in_dtype = lanes[0].dtype
    cdtype = _scan_dtype(precision, in_dtype)
    lanes = tuple(a.astype(cdtype) for a in lanes)
    combine = functools.partial(_combine_lanes, block_b=block_b,
                                interpret=interpret)
    out = _scan_lanes(lanes, combine)
    return _from_lanes(tuple(a.astype(in_dtype) for a in out))


def kernel_suffix_scan(elems: LQTElement, *, block_b: int = 512,
                       interpret: bool = False,
                       precision: str = "default") -> LQTElement:
    """Inclusive suffix combine along axis 0 (earlier operand first):
    ``out[i] = a_i (x) ... (x) a_{T-1}``, matching
    :func:`repro.core.pscan.suffix_scan` -- flip on the lane axis plus an
    operand swap, so non-commutativity is preserved."""
    lanes = _to_lanes(elems)
    in_dtype = lanes[0].dtype
    cdtype = _scan_dtype(precision, in_dtype)
    flipped = tuple(jnp.flip(a.astype(cdtype), axis=-1) for a in lanes)

    def swapped(a, b):
        return _combine_lanes(b, a, block_b=block_b, interpret=interpret)

    out = _scan_lanes(flipped, swapped)
    out = tuple(jnp.flip(a, axis=-1).astype(in_dtype) for a in out)
    return _from_lanes(out)


def scan_combine_fn(*, block_b: int = 512, interpret: bool = False):
    """Combine callable for ``repro.core.pscan`` scans: kernel-backed and
    broadcast-compatible (rank-promotes a carried single element)."""

    def fn(a: LQTElement, b: LQTElement) -> LQTElement:
        def rank_of(e):
            return e.A.ndim

        if rank_of(a) == 2 and rank_of(b) == 3:
            a = jax.tree_util.tree_map(
                lambda x, y: jnp.broadcast_to(x, y.shape), a, b)
        elif rank_of(b) == 2 and rank_of(a) == 3:
            b = jax.tree_util.tree_map(
                lambda x, y: jnp.broadcast_to(x, y.shape), b, a)
        if rank_of(a) == 2:
            a3 = jax.tree_util.tree_map(lambda x: x[None], a)
            b3 = jax.tree_util.tree_map(lambda x: x[None], b)
            out = lqt_combine_batched(a3, b3, block_b=8,
                                      interpret=interpret)
            return jax.tree_util.tree_map(lambda x: x[0], out)
        return lqt_combine_batched(a, b, block_b=block_b,
                                   interpret=interpret)

    return fn
