"""Mesh surface (``MeshSpec``) + logical-axis sharding rules.

``MeshSpec`` is THE way to hand the estimation system a device mesh: one
frozen description of the 2-D (time x batch) device layout consumed by
:class:`repro.core.estimator.Estimator`, ``serving.TrajectoryEngine`` and
the ``method="distributed"`` solver alike.  ``.build()`` materialises the
``jax.sharding.Mesh``; ``.activate()`` enters :func:`mesh_context` so
ambient consumers (the distributed solver resolving its time axis via
:func:`resolve_time_mesh`, model code using :func:`logical_constraint`)
see the same mesh.  Everywhere a ``mesh=`` argument is accepted, a raw
``Mesh`` keeps working -- :func:`as_mesh` normalises either form.

The rest of this module is the LOGICAL axis-name rules (DP/TP/EP/SP)
with divisibility fallback.  Parameters and activations are annotated
with LOGICAL axis names ("embed", "heads", "ff", "vocab", "experts",
...).  ``choose_pspec`` maps a logical shape to a concrete
``PartitionSpec`` for the active mesh:

* exactly one tensor dimension is model-sharded, picked by walking
  ``MODEL_PRIORITY`` and taking the first logical axis that is present AND
  whose size is divisible by the mesh's model-axis size (llava's 56 q-heads
  do not divide 16 -> falls through to the 128 head_dim; granite's 40
  experts fall through to d_ff);
* the "batch" axis shards over ("pod", "data") (the pod axis is folded into
  data parallelism);
* optimizer-state tensors may additionally shard their largest replicated
  dimension over "data" (ZeRO-1), handled in ``train/optimizer.py``.

``logical_constraint`` applies ``with_sharding_constraint`` when called
under an active mesh context and is a no-op otherwise, so model code is
mesh-agnostic and single-device tests run unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority of logical axes for the single model-sharded dimension
MODEL_PRIORITY: Sequence[str] = (
    "experts", "vocab", "ff", "heads", "kv_heads", "ssm_inner", "ssm_x",
    "ssm_heads", "head", "embed_model",
)

# logical axes that shard over the data (+pod) axes
BATCH_AXES = ("batch",)

# logical axes that may shard over data for sequence parallelism (opt-in)
SEQ_AXES = ("seq_sp",)


class _MeshContext(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.data_axes: tuple = ("data",)
        self.model_axis: str = "model"
        self.tp_exclude: frozenset = frozenset()


_CTX = _MeshContext()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, *, batch_axes: tuple = None,
                 tp_exclude=()):
    """Activate logical->physical rules for ``mesh``.

    Meshes with a "pod" axis fold it into the batch sharding.

    ``batch_axes`` overrides the mesh axes used for batch/zero1 sharding
    (e.g. ("pod", "data", "model") for the dp-only policy on small
    models); ``tp_exclude`` removes logical names from the model-sharding
    priority (e.g. everything but "vocab" under dp-only).
    """
    prev = (_CTX.mesh, _CTX.data_axes, _CTX.model_axis, _CTX.tp_exclude)
    _CTX.mesh = mesh
    axis_names = mesh.axis_names
    if batch_axes is not None:
        _CTX.data_axes = tuple(a for a in batch_axes if a in axis_names)
    else:
        _CTX.data_axes = tuple(a for a in ("pod", "data")
                               if a in axis_names)
    _CTX.model_axis = "model" if "model" in axis_names else None
    _CTX.tp_exclude = frozenset(tp_exclude)
    try:
        with mesh:
            yield mesh
    finally:
        (_CTX.mesh, _CTX.data_axes, _CTX.model_axis,
         _CTX.tp_exclude) = prev


def data_parallel_size(mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return 1
    return _axis_size(mesh, tuple(_CTX.data_axes)) if _CTX.data_axes else 1


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


# ---------------------------------------------------------------------------
# MeshSpec: the one mesh entry point
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """One declarative description of the 2-D (time x batch) device mesh.

    ``time`` devices shard the TIME axis (the ``method="distributed"``
    associative scan, :func:`repro.core.pscan.sharded_scan`); ``batch``
    devices shard the REQUEST axis (stacked-problem batches,
    ``TrajectoryEngine`` waves).  Either may be 1 -- the axis is still
    named in the mesh, so the same spec works for time-only, batch-only
    and fully 2-D layouts.  Total devices used: ``time * batch`` (the
    first that many of ``jax.devices()`` unless ``.build(devices=...)``
    is given an explicit sequence).

    Pass a ``MeshSpec`` anywhere a ``mesh=`` argument is accepted
    (``Estimator``, ``TrajectoryEngine``) or enter ``.activate()`` to
    make it ambient for mesh-aware code (the distributed solver picks it
    up via :func:`resolve_time_mesh`).
    """

    time: int = 1
    batch: int = 1
    time_axis: str = "time"
    batch_axis: str = "data"

    def __post_init__(self) -> None:
        for field, v in (("time", self.time), ("batch", self.batch)):
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"MeshSpec.{field} must be a positive int, got {v!r}")
        for field, v in (("time_axis", self.time_axis),
                         ("batch_axis", self.batch_axis)):
            if not isinstance(v, str) or not v:
                raise ValueError(
                    f"MeshSpec.{field} must be a non-empty str, got {v!r}")
        if self.time_axis == self.batch_axis:
            raise ValueError(
                f"time_axis and batch_axis must differ, both "
                f"{self.time_axis!r}")

    @property
    def num_devices(self) -> int:
        return self.time * self.batch

    def build(self, devices: Optional[Sequence] = None) -> Mesh:
        """Materialise the ``jax.sharding.Mesh``: ``(time, batch)`` over
        ``(time_axis, batch_axis)`` on the first ``time * batch`` devices."""
        devices = list(jax.devices()) if devices is None else list(devices)
        need = self.num_devices
        if need > len(devices):
            raise ValueError(
                f"MeshSpec needs {need} devices "
                f"({self.time} x {self.batch}), only {len(devices)} "
                f"available")
        arr = np.asarray(devices[:need]).reshape(self.time, self.batch)
        return Mesh(arr, (self.time_axis, self.batch_axis))

    def activate(self):
        """Context manager: build the mesh and enter :func:`mesh_context`
        so ambient consumers (``resolve_time_mesh``,
        ``logical_constraint``) see it."""
        return mesh_context(self.build(), batch_axes=(self.batch_axis,))


def as_mesh(mesh) -> Optional[Mesh]:
    """Normalise the public ``mesh=`` argument: ``None`` | ``Mesh`` |
    ``MeshSpec`` -> ``Optional[Mesh]``."""
    if mesh is None or isinstance(mesh, Mesh):
        return mesh
    if isinstance(mesh, MeshSpec):
        return mesh.build()
    raise TypeError(
        f"mesh must be None, a jax.sharding.Mesh or a MeshSpec, got "
        f"{type(mesh).__name__}")


def mesh_fingerprint(mesh: Optional[Mesh]) -> Optional[Tuple]:
    """A hashable identity for WHICH mesh an executable was compiled
    under: axis names + mesh shape + backend + exact device ids.  Part of
    the executable-cache key so an executable compiled under one mesh is
    never replayed under another (the meshes' collectives differ even
    when argument shapes agree)."""
    if mesh is None:
        return None
    devs = tuple(d.id for d in mesh.devices.flat)
    platform = mesh.devices.flat[0].platform
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape), platform,
            devs)


@functools.lru_cache(maxsize=32)
def _default_time_mesh(time_axis: str, n: int) -> Mesh:
    return Mesh(np.asarray(jax.devices()[:n]), (time_axis,))


def resolve_time_mesh(time_axis: str, *, devices_per_time: Optional[int]
                      = None, mesh: Optional[Mesh] = None) -> Optional[Mesh]:
    """The mesh a time-axis-sharded solve should run under.

    Resolution order: an explicit ``mesh`` carrying ``time_axis``, else
    the ambient :func:`mesh_context` / :meth:`MeshSpec.activate` mesh
    carrying it, else a default 1-D mesh over ``devices_per_time``
    devices (all local devices when ``None``).  Returns ``None`` when
    fewer than 2 time-shards are available -- the caller decides whether
    that falls back to the single-device scan or errors
    (``DistributedOptions.fallback``).
    """
    for candidate in (mesh, _CTX.mesh):
        if candidate is not None and time_axis in candidate.axis_names:
            if (devices_per_time is not None
                    and candidate.shape[time_axis] != devices_per_time):
                raise ValueError(
                    f"devices_per_time={devices_per_time} but the mesh's "
                    f"{time_axis!r} axis has size "
                    f"{candidate.shape[time_axis]}")
            return candidate
    avail = len(jax.devices())
    n = avail if devices_per_time is None else devices_per_time
    if n > avail:
        raise ValueError(
            f"devices_per_time={n} exceeds the {avail} available devices")
    if n < 2:
        return None
    return _default_time_mesh(time_axis, n)


def _axis_size(mesh: Mesh, names) -> int:
    size = 1
    for n in names if isinstance(names, tuple) else (names,):
        size *= mesh.shape[n]
    return size


def choose_pspec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None) -> P:
    """Map logical axes to a PartitionSpec under the active mesh."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    assert len(shape) == len(logical), (shape, logical)
    entries: list = [None] * len(shape)

    # batch / ZeRO-1 axes -> the data axes, with progressive fallback to
    # fewer axes when the dimension does not divide the full product
    # (e.g. batch 256 on a 512-chip dp-only layout).
    for i, name in enumerate(logical):
        if name in BATCH_AXES + ("zero1",) and _CTX.data_axes:
            axes = tuple(_CTX.data_axes)
            while axes:
                if shape[i] % _axis_size(mesh, axes) == 0:
                    entries[i] = axes if len(axes) > 1 else axes[0]
                    break
                axes = axes[1:]

    def used_axes() -> set:
        out = set()
        for e in entries:
            if e is None:
                continue
            out.update(e if isinstance(e, tuple) else (e,))
        return out

    # sequence-parallel axis -> the model axis (megatron-style SP)
    if _CTX.model_axis is not None and _CTX.model_axis not in used_axes():
        msize = mesh.shape[_CTX.model_axis]
        for i, name in enumerate(logical):
            if name in SEQ_AXES and entries[i] is None \
                    and shape[i] % msize == 0:
                entries[i] = _CTX.model_axis
                break

    # one model-sharded dim by priority with divisibility fallback
    if _CTX.model_axis is not None and _CTX.model_axis not in used_axes():
        msize = mesh.shape[_CTX.model_axis]
        for cand in MODEL_PRIORITY:
            if cand in _CTX.tp_exclude:
                continue
            placed = False
            for i, name in enumerate(logical):
                if name == cand and entries[i] is None \
                        and shape[i] % msize == 0 and shape[i] >= msize:
                    entries[i] = _CTX.model_axis
                    placed = True
                    break
            if placed:
                break
    return P(*entries)


def logical_constraint(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = choose_pspec(x.shape, logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape, logical, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, choose_pspec(shape, logical, mesh))


def tree_pspecs(axes_tree, shapes_tree, mesh: Optional[Mesh] = None):
    """Map a tree of logical-axes tuples + shapes to PartitionSpecs."""
    mesh = mesh or _CTX.mesh
    return jax.tree_util.tree_map(
        lambda ax, shp: choose_pspec(shp, ax, mesh),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, shapes_tree, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    specs = tree_pspecs(axes_tree, shapes_tree, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def shard_over_batch(fn, mesh: Mesh, batch_axis: str,
                     arg_batched: Sequence[bool]):
    """Wrap a batched function so its leading batch axis spreads over
    ``mesh.shape[batch_axis]`` devices with ``shard_map``.

    ``arg_batched[i]`` marks whether positional arg ``i`` carries the batch
    axis (sharded) or is shared across requests (replicated).  Outputs are
    sharded over the batch axis.  This is the REQUEST-axis decomposition
    used by ``repro.core.batching`` / the ``TrajectoryEngine`` -- the
    complement of the time-axis ``core.pscan.distributed_scan``.
    """
    try:                                   # jax >= 0.6 top-level API
        from jax import shard_map
    except ImportError:                    # older releases
        from jax.experimental.shard_map import shard_map

    in_specs = tuple(P(batch_axis) if b else P() for b in arg_batched)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=P(batch_axis))
