"""Deterministic synthetic data pipelines.

Stateless by construction: ``batch_at(step)`` is a pure function of
(seed, step, shard), so restarts, elastic resharding, and straggler replays
produce bit-identical batches with no data-loader state to checkpoint
(only the step counter, which lives in the optimizer state).

Two generators:
* ``LMDataPipeline`` -- noisy-copy language modelling: each sequence tiles
  a per-sequence random segment with corruptions; learnable by attending
  to the previous period (loss floor ~= corruption entropy).
* ``TrajectoryDataPipeline`` -- simulated SDE measurement records for the
  estimation examples/benchmarks (wraps core.simulate_*).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMDataPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    period: int = 64
    corruption: float = 0.1
    embed_dim: int = 0           # >0 -> also emit frame/patch embeddings

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        ks, kc, kn, ke = jax.random.split(key, 4)
        B, S, P = self.global_batch, self.seq_len, self.period
        seg = jax.random.randint(ks, (B, P), 0, self.vocab_size)
        reps = (S + P) // P + 1
        toks = jnp.tile(seg, (1, reps))[:, :S + 1]
        corrupt = jax.random.bernoulli(kc, self.corruption, toks.shape)
        noise = jax.random.randint(kn, toks.shape, 0, self.vocab_size)
        toks = jnp.where(corrupt, noise, toks).astype(jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.embed_dim:
            # stub modality frontend: embeddings derived deterministically
            # from the tokens through a fixed random codebook
            code = jax.random.normal(
                jax.random.PRNGKey(self.seed + 7),
                (self.vocab_size, self.embed_dim), jnp.float32) * 0.02
            batch["embeddings"] = jnp.take(code, batch["tokens"], axis=0)
        return batch


@dataclasses.dataclass(frozen=True)
class TrajectoryDataPipeline:
    """Batches of simulated measurement records for MAP estimation."""
    model: object            # LinearSDE | NonlinearSDE
    ts: jnp.ndarray
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        from repro.core import simulate_linear, simulate_nonlinear
        from repro.core.sde import LinearSDE
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        keys = jax.random.split(key, self.batch)
        sim = simulate_linear if isinstance(self.model, LinearSDE) \
            else simulate_nonlinear
        xs, ys = jax.vmap(lambda k: sim(self.model, self.ts, k))(keys)
        return {"x_true": xs, "y": ys}
