"""Jitted wrappers for the LQT-combine Pallas kernel.

``lqt_combine_batched`` takes the natural (B, nx, nx)/(B, nx) layout,
re-lays out to the kernel's lane-major form (batch minor), pads B to the
block size, runs the kernel and restores the layout.  When the whole scan
runs kernel-side, keep the lane-major layout across levels instead (see
``scan_combine_fn``) so the transposes happen once, not per level.

On non-TPU backends (this container) ``interpret=True`` executes the kernel
body with the Pallas interpreter -- bit-accurate semantics, no Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import LQTElement

from .kernel import lqt_combine_lanes


def _to_lanes(e: LQTElement):
    return (
        jnp.transpose(e.A, (1, 2, 0)),
        jnp.transpose(e.b, (1, 0)),
        jnp.transpose(e.C, (1, 2, 0)),
        jnp.transpose(e.eta, (1, 0)),
        jnp.transpose(e.J, (1, 2, 0)),
    )


def _from_lanes(ops) -> LQTElement:
    A, b, C, eta, J = ops
    return LQTElement(
        jnp.transpose(A, (2, 0, 1)), jnp.transpose(b, (1, 0)),
        jnp.transpose(C, (2, 0, 1)), jnp.transpose(eta, (1, 0)),
        jnp.transpose(J, (2, 0, 1)))


def _pad_lanes(ops, pad):
    if pad == 0:
        return ops
    out = []
    for a in ops:
        width = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
        out.append(jnp.pad(a, width))
    return tuple(out)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lqt_combine_batched(e1: LQTElement, e2: LQTElement, *,
                        block_b: int = 512,
                        interpret: bool = False) -> LQTElement:
    """Kernel-backed eq. (42) combine on (B, nx, nx)-layout elements."""
    B = e1.A.shape[0]
    if B == 0:  # associative_scan emits empty combines at some tree levels
        return e1
    bb = min(block_b, max(8, B))
    pad = (-B) % bb
    ops1 = _pad_lanes(_to_lanes(e1), pad)
    ops2 = _pad_lanes(_to_lanes(e2), pad)
    # padded lanes carry zeros: C1 J2 = 0 -> M = I, well-defined garbage-free
    out = lqt_combine_lanes(ops1, ops2, block_b=bb, interpret=interpret)
    out = tuple(a[..., :B] for a in out)
    return _from_lanes(out)


def scan_combine_fn(*, block_b: int = 512, interpret: bool = False):
    """Combine callable for ``repro.core.pscan`` scans: kernel-backed and
    broadcast-compatible (rank-promotes a carried single element)."""

    def fn(a: LQTElement, b: LQTElement) -> LQTElement:
        def rank_of(e):
            return e.A.ndim

        if rank_of(a) == 2 and rank_of(b) == 3:
            a = jax.tree_util.tree_map(
                lambda x, y: jnp.broadcast_to(x, y.shape), a, b)
        elif rank_of(b) == 2 and rank_of(a) == 3:
            b = jax.tree_util.tree_map(
                lambda x, y: jnp.broadcast_to(x, y.shape), b, a)
        if rank_of(a) == 2:
            a3 = jax.tree_util.tree_map(lambda x: x[None], a)
            b3 = jax.tree_util.tree_map(lambda x: x[None], b)
            out = lqt_combine_batched(a3, b3, block_b=8,
                                      interpret=interpret)
            return jax.tree_util.tree_map(lambda x: x[0], out)
        return lqt_combine_batched(a, b, block_b=block_b,
                                   interpret=interpret)

    return fn
