"""Iterated linearisation for nonlinear models (section 4.4).

Continuous-time iterated extended Kalman smoother: linearise (1) about the
current nominal trajectory, solve the resulting linear-affine MAP problem
with the sequential or PARALLEL smoother, re-linearise, repeat.  Every
iteration is parallel-in-time when ``method`` is a parallel solver, which is
exactly the paper's Fig.-2 experiment (5 iterations on the coordinated-turn
model).

The default drops the second-order Onsager-Machlup divergence correction
(as the paper's IEKS does -- for linear-affine subproblems div f~ is
constant); ``divergence_correction=True`` folds the linearised 1/2 div f
term in as an extra linear running cost (DESIGN.md S1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .parallel import parallel_rts, parallel_two_filter
from .sde import NonlinearSDE, grid_lqt_from_nonlinear
from .sequential import sequential_rts, sequential_two_filter
from .types import MAPSolution


def _solve(grid, method: str, nsub: int, mode: str) -> MAPSolution:
    if method == "parallel_rts":
        return parallel_rts(grid, nsub, mode)
    if method == "parallel_two_filter":
        return parallel_two_filter(grid, nsub, mode)
    if method == "sequential_rts":
        return sequential_rts(grid, mode)
    if method == "sequential_two_filter":
        return sequential_two_filter(grid, mode)
    raise ValueError(f"unknown method: {method}")


def iterated_map(
    model: NonlinearSDE,
    ts: jnp.ndarray,
    y: jnp.ndarray,
    *,
    iterations: int = 5,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    divergence_correction: bool = False,
    x_init: jnp.ndarray | None = None,
) -> MAPSolution:
    """Continuous-time iterated MAP estimation (paper section 4.4/5.2).

    ``iterations`` fixed Gauss-Newton style passes (paper uses 5); the
    initial nominal trajectory defaults to the constant prior mean.
    Returns the MAP solution from the final linearisation.
    """
    N = y.shape[0]
    if x_init is None:
        x_init = jnp.broadcast_to(model.m0, (N + 1,) + model.m0.shape)

    def body(xbar, _):
        grid = grid_lqt_from_nonlinear(
            model, ts, y, xbar, divergence_correction=divergence_correction)
        sol = _solve(grid, method, nsub, mode)
        return sol.x, None

    # iterations-1 passes inside lax.scan (keeps the compiled graph O(1) in
    # iteration count), plus one final pass returning the full solution --
    # ``iterations`` linearise+solve passes total, matching the paper.
    x_last, _ = jax.lax.scan(body, x_init, None, length=iterations - 1)
    grid = grid_lqt_from_nonlinear(
        model, ts, y, x_last, divergence_correction=divergence_correction)
    return _solve(grid, method, nsub, mode)
