from .engine import Request, ServeEngine
from .trajectory import TrajectoryEngine

__all__ = ["Request", "ServeEngine", "TrajectoryEngine"]
