"""Beyond-paper features: RK4 element integration, perf-knob exactness
(the optimisation knobs must never change results, only cost)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    grid_lqt_from_linear, parallel_rts, sequential_rts, simulate_linear,
    time_grid,
)

from helpers import wiener_velocity


def _refine_grid(grid, k: int):
    """Subdivide every substep into k equal pieces with identical
    piecewise-constant coefficients/measurements: the SAME continuous
    problem, integrated k-times finer (a convergence reference)."""
    from repro.core.types import GridLQT

    def rep(a, scale=1.0):
        if a is None:
            return None
        out = jnp.repeat(a, k, axis=0)
        return out * scale if scale != 1.0 else out

    return GridLQT(
        dt=rep(grid.dt, 1.0 / k), F=rep(grid.F), c=rep(grid.c),
        H=rep(grid.H), r=rep(grid.r), Q=rep(grid.Q), Rinv=rep(grid.Rinv),
        y=rep(grid.y), S_T=grid.S_T, v_T=grid.v_T,
        lin=rep(grid.lin))


def test_rk4_beats_euler_accuracy():
    """Against a converged fine-integration reference of the SAME
    piecewise-constant problem, RK4 elements are far more accurate than
    the paper's explicit Euler at equal step count.

    (Comparing against ``discrete`` mode would be wrong: that is the
    exact solution of the Euler-DISCRETISED problem, which RK4 rightly
    disagrees with.)
    """
    model = wiener_velocity()
    T, n, k = 64, 10, 16
    ts = time_grid(0.0, 5.0, T * n)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
    grid = grid_lqt_from_linear(model, ts, y)
    fine = _refine_grid(grid, k)
    ref = parallel_rts(fine, n * k, "rk4").x[::k]
    err_eu = float(jnp.max(jnp.abs(parallel_rts(grid, n, "euler").x - ref)))
    err_rk = float(jnp.max(jnp.abs(parallel_rts(grid, n, "rk4").x - ref)))
    assert err_rk < err_eu / 3, (err_rk, err_eu)


def test_rk4_parallel_more_stable_than_sequential():
    """A structural finding worth pinning: the parallel decomposition is
    MORE stable than sequential integration at equal order.  The
    sequential Riccati RK4 must integrate through the stiff S(tau_f)=1/P0
    transient (dt*Q*S outside the RK4 stability region at this grid); the
    parallel path integrates non-stiff BLOCK-LOCAL element ODEs from the
    identity boundary and handles the stiffness algebraically in the
    exact combine (42).  Hence parallel-RK4 lands closer to the converged
    reference than sequential-RK4."""
    model = wiener_velocity()
    T, n, k = 64, 10, 16
    ts = time_grid(0.0, 5.0, T * n)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(2))
    grid = grid_lqt_from_linear(model, ts, y)
    ref = parallel_rts(_refine_grid(grid, k), n * k, "rk4").x[::k]
    err_par = float(jnp.max(jnp.abs(parallel_rts(grid, n, "rk4").x - ref)))
    err_seq = float(jnp.max(jnp.abs(sequential_rts(grid, "rk4").x - ref)))
    # measured: par-rk4 ~0.09 vs seq-rk4 ~6.6 (70x) -- the sequential
    # error is dominated by the stiff S(tau_f)=100 transient regardless
    # of integrator order
    assert err_par < err_seq / 10, (err_par, err_seq)
    assert err_par < 0.15, err_par


def test_chunked_attention_chunk_invariance():
    """chunk sizes are a pure cost knob: results identical."""
    from repro.models.attention import chunked_mha
    from repro.kernels.flash_attention import mha_ref
    rng = np.random.default_rng(0)
    B, Hq, Hkv, L, D = 2, 4, 2, 128, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, L, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, L, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, L, D)), jnp.float32)
    want = mha_ref(q, k, v, causal=True)
    for cq, ck in [(128, 128), (32, 64), (16, 16), (128, 32)]:
        got = chunked_mha(q, k, v, causal=True, window=None,
                          chunk_q=cq, chunk_k=ck)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_causal_skip_is_exact():
    """the triangular schedule changes FLOPs, not results."""
    from repro.models.attention import chunked_mha
    rng = np.random.default_rng(1)
    B, Hq, Hkv, L, D = 1, 4, 4, 96, 8
    q = jnp.asarray(rng.standard_normal((B, Hq, L, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, L, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, L, D)), jnp.float32)
    a = chunked_mha(q, k, v, causal=True, window=None, chunk_q=16,
                    chunk_k=16, causal_skip=False)
    b = chunked_mha(q, k, v, causal=True, window=None, chunk_q=16,
                    chunk_k=16, causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_ssd_chunk_invariance():
    """SSD chunk length is a pure cost knob."""
    from repro.models.ssm import ssd_scan_jnp
    rng = np.random.default_rng(2)
    b, L, H, P, S = 2, 96, 4, 16, 8
    x = jnp.asarray(rng.standard_normal((b, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.2, 1.5, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, L, 1, S)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, L, 1, S)), jnp.float32)
    D = jnp.ones((H,), jnp.float32)
    ref = ssd_scan_jnp(x, dt, A, B, C, D, chunk=96)
    for chunk in (8, 16, 32, 48):
        got = ssd_scan_jnp(x, dt, A, B, C, D, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)


def test_kv_replicate_is_exact():
    """kv_replicate changes sharding metadata only, never math."""
    import dataclasses
    from repro.config import get_config
    from repro.models import transformer
    cfg = dataclasses.replace(get_config("qwen3-4b-smoke"),
                              dtype="float32")
    cfg_r = dataclasses.replace(cfg, kv_replicate=True)
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    a = transformer.train_loss(params, batch, cfg)
    b = transformer.train_loss(params, batch, cfg_r)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-7)
