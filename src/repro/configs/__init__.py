"""Config registry: the ten assigned architectures + the paper's own
estimation experiment configs.

Every architecture module exposes ``config()`` (the exact published
configuration) and ``smoke_config()`` (a reduced same-family configuration
for CPU smoke tests).  ``ARCHS`` lists the assigned ids; shape suites live
in ``repro.config.SHAPE_SUITE``.
"""
from repro.config import register_config

from . import (
    coordinated_turn,
    granite_moe_3b,
    h2o_danube_1_8b,
    hubert_xlarge,
    hymba_1_5b,
    llava_next_34b,
    mamba2_370m,
    phi35_moe_42b,
    qwen3_4b,
    smollm_135m,
    starcoder2_15b,
    wiener_velocity,
)

ARCHS = (
    "hubert-xlarge",
    "mamba2-370m",
    "llava-next-34b",
    "hymba-1.5b",
    "smollm-135m",
    "qwen3-4b",
    "h2o-danube-1.8b",
    "starcoder2-15b",
    "phi3.5-moe-42b-a6.6b",
    "granite-moe-3b-a800m",
)

_MODULES = {
    "hubert-xlarge": hubert_xlarge,
    "mamba2-370m": mamba2_370m,
    "llava-next-34b": llava_next_34b,
    "hymba-1.5b": hymba_1_5b,
    "smollm-135m": smollm_135m,
    "qwen3-4b": qwen3_4b,
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "starcoder2-15b": starcoder2_15b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "granite-moe-3b-a800m": granite_moe_3b,
}

for _name, _mod in _MODULES.items():
    register_config(_name, _mod.config)
    register_config(_name + "-smoke", _mod.smoke_config)


def arch_module(name: str):
    return _MODULES[name]
