"""h2o-danube-1.8b: 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000,
llama+mistral mix with sliding-window attention [arXiv:2401.16818].

SWA (4096) bounds the decode cache, so long_500k runs with a rolling
window cache (DESIGN.md S4)."""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000, window=4096, remat_group=6)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="h2o-danube-1.8b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, window=32)
