"""Continuous-time MAP trajectory estimation, parallel-in-time.

Implements Razavi, Garcia-Fernandez & Sarkka (2025), "Temporal
parallelisation of continuous-time maximum-a-posteriori trajectory
estimation": parallel Kalman-Bucy filtering, parallel continuous-time RTS
and two-filter smoothing, and iterated linearisation for nonlinear models,
all built on associative scans.

The public surface is the ``Estimator``/``Problem``/``Solution`` triple:

    est = Estimator(model, method="parallel_rts",
                    options=ParallelOptions(nsub=10, mode="discrete"))
    sol = est.solve(Problem.single(model, ts, y))   # -> Solution

Methods and their option dataclasses live in the registry
(:func:`register_method` / :func:`method_names`).  The old function entry
points (``map_estimate`` & co.) remain as deprecation shims; see
``docs/MIGRATION.md``.
"""
from .api import map_estimate
from .batching import map_estimate_batched, map_estimate_ragged
from .combine import (
    affine_combine,
    apply_element_to_value,
    elem_min_initial,
    lqt_combine,
    value_as_element,
)
from .estimator import (
    Estimator,
    ExecutableCache,
    Problem,
    cache_stats,
    clear_cache,
    legacy_options,
)
from .nonlinear import iterated_map, iterated_solve
from .options import (
    DistributedOptions,
    IteratedOptions,
    KernelOptions,
    ParallelOptions,
    SequentialOptions,
    SigmaPointOptions,
    SolverOptions,
    TwoFilterOptions,
)
from .oracle import qp_map_estimate, qp_map_from_grid
from .padding import bucket_length, pad_record, slice_solution
from .registry import (
    MethodSpec,
    get_method,
    get_solver,
    method_names,
    register_method,
)
from .parallel import parallel_backward, parallel_rts, parallel_two_filter
from .pscan import distributed_scan, prefix_scan, sharded_scan, suffix_scan
from .sde import (
    LinearSDE,
    NonlinearSDE,
    build_grid_lqt,
    grid_lqt_from_linear,
    grid_lqt_from_nonlinear,
    om_cost_grid,
    om_cost_linear,
    om_cost_nonlinear,
    simulate_linear,
    simulate_nonlinear,
    time_grid,
)
from .sequential import (
    sequential_backward,
    sequential_rts,
    sequential_two_filter,
)
from .types import (
    AffineElement,
    BucketInfo,
    GridLQT,
    LQTElement,
    MAPSolution,
    PaddingReport,
    Solution,
    ValueFn,
)

__all__ = [
    # unified surface
    "Estimator", "Problem", "Solution",
    "SolverOptions", "SequentialOptions", "ParallelOptions",
    "TwoFilterOptions", "KernelOptions", "DistributedOptions",
    "IteratedOptions", "SigmaPointOptions",
    "PaddingReport", "BucketInfo", "ExecutableCache",
    "cache_stats", "clear_cache",
    # registry
    "MethodSpec", "get_method", "get_solver", "method_names",
    "register_method", "METHODS",
    # models / types
    "AffineElement", "GridLQT", "LQTElement", "MAPSolution", "ValueFn",
    "LinearSDE", "NonlinearSDE",
    # solver building blocks
    "parallel_backward", "parallel_rts", "parallel_two_filter",
    "sequential_backward", "sequential_rts", "sequential_two_filter",
    "prefix_scan", "suffix_scan", "distributed_scan", "sharded_scan",
    "lqt_combine", "affine_combine", "apply_element_to_value",
    "value_as_element", "elem_min_initial",
    "build_grid_lqt", "grid_lqt_from_linear", "grid_lqt_from_nonlinear",
    "simulate_linear", "simulate_nonlinear", "time_grid",
    "om_cost_grid", "om_cost_linear", "om_cost_nonlinear",
    "qp_map_estimate", "qp_map_from_grid",
    "iterated_solve",
    "bucket_length", "pad_record", "slice_solution",
    # deprecated shims + migration helper
    "map_estimate", "iterated_map",
    "map_estimate_batched", "map_estimate_ragged",
    "legacy_options",
]


def __getattr__(name: str):
    if name == "METHODS":      # deprecated live view; see api.__getattr__
        from . import api
        return api.METHODS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
