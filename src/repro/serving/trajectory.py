"""Trajectory-estimation serving engine: MAP solves as a batched service.

``TrajectoryEngine`` is the estimation-workload sibling of
:class:`~repro.serving.engine.ServeEngine`: instead of LM decode steps it
serves :func:`~repro.core.map_estimate` requests.  The same production
tricks apply:

* **fixed-batch padding** -- every wave is exactly ``batch`` rows, so each
  bucket length compiles ONE executable, reused forever (the executable
  cache lives in :mod:`repro.core.batching`);
* **pad-and-bucket** -- ragged record lengths are padded to power-of-two
  block counts with masked measurements (exact, see ``batching``);
* **row recycling / continuous batching** -- short waves are topped up by
  recycling a live row, and the queue is drained in FIFO waves grouped by
  bucket so one submit/collect cycle serves any mix of lengths;
* **optional batch-axis sharding** -- pass a mesh (e.g. from
  :func:`repro.launch.mesh.make_host_mesh`) and each wave is ``shard_map``-
  sharded over the mesh's data axis, spreading requests across devices.

API: ``submit(ts, y) -> ticket``; ``step()`` solves one wave; ``collect()``
pops finished ``(ticket, MAPSolution)`` pairs; ``estimate(records)`` is the
synchronous convenience wrapper.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.batching import (
    bucket_length,
    map_estimate_batched,
    pad_record,
    slice_solution,
)
from repro.core.sde import LinearSDE, NonlinearSDE
from repro.core.types import MAPSolution


@dataclasses.dataclass
class _Pending:
    ticket: int
    ts: np.ndarray
    y: np.ndarray
    n_pad: int


class TrajectoryEngine:
    """Queued, batched MAP-estimation service for one model.

    Args:
      model: shared :class:`LinearSDE` / :class:`NonlinearSDE`.
      batch: fixed wave size (compiled batch).  With a mesh it must be
        divisible by the mesh's ``batch_axis`` size.
      method / nsub / mode / iterations / divergence_correction: forwarded
        to :func:`~repro.core.map_estimate` for every request.
      bucket_sizes: optional explicit padded-length buckets (multiples of
        ``nsub``); default is power-of-two block counts.
      mesh: optional ``jax.sharding.Mesh`` for batch-axis sharding.
    """

    def __init__(
        self,
        model: Union[LinearSDE, NonlinearSDE],
        *,
        batch: int = 8,
        method: str = "parallel_rts",
        nsub: int = 10,
        mode: str = "euler",
        iterations: int = 5,
        divergence_correction: bool = False,
        bucket_sizes: Optional[Sequence[int]] = None,
        mesh=None,
        batch_axis: str = "data",
    ):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if mesh is not None and batch % mesh.shape[batch_axis]:
            raise ValueError(
                f"batch {batch} not divisible by mesh axis "
                f"{batch_axis!r} size {mesh.shape[batch_axis]}")
        self.model = model
        self.batch = batch
        self.method = method
        self.nsub = nsub
        self.mode = mode
        self.iterations = iterations
        self.divergence_correction = divergence_correction
        self.bucket_sizes = bucket_sizes
        self.mesh = mesh
        self.batch_axis = batch_axis

        self._queue: Deque[_Pending] = collections.deque()
        self._done: Dict[int, MAPSolution] = {}
        self._next_ticket = 0
        self.waves = 0            # compiled-batch solves issued
        self.recycled_rows = 0    # padding rows recycled into short waves

    # -- submit / collect ---------------------------------------------------

    def submit(self, ts: np.ndarray, y: np.ndarray) -> int:
        """Enqueue one record; returns a ticket redeemable at collect()."""
        ts = np.asarray(ts)
        y = np.asarray(y)
        if y.ndim != 2 or y.shape[0] < 1:
            raise ValueError(
                f"y must be (N, ny) with N >= 1, got shape {y.shape}")
        if ts.shape != (y.shape[0] + 1,):
            raise ValueError(
                f"ts must be (N+1,) = {(y.shape[0] + 1,)}, got {ts.shape}")
        ticket = self._next_ticket
        self._next_ticket += 1
        n_pad = bucket_length(y.shape[0], self.nsub, self.bucket_sizes)
        self._queue.append(_Pending(ticket, ts, y, n_pad))
        return ticket

    def pending(self) -> int:
        return len(self._queue)

    def collect(self) -> List[Tuple[int, MAPSolution]]:
        """Pop all finished (ticket, solution) pairs, ticket order."""
        out = sorted(self._done.items())
        self._done.clear()
        return out

    # -- wave processing ----------------------------------------------------

    def _take_wave(self) -> List[_Pending]:
        """FIFO wave: the oldest request fixes the bucket; later same-bucket
        requests top the wave up to ``batch`` (others keep their place).
        Scanning stops as soon as the wave is full, so draining Q queued
        requests is O(Q), not O(Q^2/batch)."""
        n_pad = self._queue[0].n_pad
        wave: List[_Pending] = []
        keep: Deque[_Pending] = collections.deque()
        while self._queue and len(wave) < self.batch:
            req = self._queue.popleft()
            if req.n_pad == n_pad:
                wave.append(req)
            else:
                keep.append(req)
        keep.extend(self._queue)           # untouched tail, order preserved
        self._queue = keep
        return wave

    def step(self) -> int:
        """Solve one fixed-size wave; returns the number of requests
        completed (0 if the queue is empty)."""
        if not self._queue:
            return 0
        wave = self._take_wave()
        n_pad = wave[0].n_pad
        padded = [pad_record(r.ts, r.y, n_pad) for r in wave]
        rows = padded + [padded[0]] * (self.batch - len(padded))
        self.recycled_rows += self.batch - len(padded)
        ts_b = jnp.asarray(np.stack([r[0] for r in rows]))
        ys_b = jnp.asarray(np.stack([r[1] for r in rows]))
        mask_b = jnp.asarray(np.stack([r[2] for r in rows]))
        sol = map_estimate_batched(
            self.model, ts_b, ys_b, method=self.method, nsub=self.nsub,
            mode=self.mode, iterations=self.iterations,
            divergence_correction=self.divergence_correction,
            measurement_mask=mask_b, mesh=self.mesh,
            batch_axis=self.batch_axis)
        self.waves += 1
        for row, req in enumerate(wave):
            self._done[req.ticket] = slice_solution(sol, row, req.y.shape[0])
        return len(wave)

    def run(self) -> int:
        """Drain the queue; returns the total number of requests solved."""
        total = 0
        while self._queue:
            total += self.step()
        return total

    # -- synchronous convenience --------------------------------------------

    def estimate(
        self, records: Sequence[Tuple[np.ndarray, np.ndarray]],
    ) -> List[MAPSolution]:
        """Submit ``(ts, y)`` records, drain, return solutions in order."""
        tickets = [self.submit(ts, y) for ts, y in records]
        self.run()
        got = dict(self.collect())
        return [got[t] for t in tickets]
