"""Element construction vs direct minimisation (eq. 41/43 ground truth)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    grid_lqt_from_linear, qp_map_from_grid, simulate_linear, time_grid,
)
from repro.core.elements import (
    discrete_block_elements, euler_block_elements, one_step_elements,
)

from helpers import random_ltv, wiener_velocity


def _dense_conditional_min(grid, j0, j1, phi, z):
    """Directly minimise the discretised reversed-time cost over the
    interior states of substeps [j0, j1) with endpoints pinned."""
    nx = grid.nx
    n_int = j1 - j0 - 1
    idx = lambda k: slice(k * nx, (k + 1) * nx)

    def cost(inner):
        states = [phi] + [inner[idx(k)] for k in range(n_int)] + [z]
        c = 0.0
        for k in range(j0, j1):
            s0 = states[k - j0]
            s1 = states[k - j0 + 1]
            dt = grid.dt[k]
            u = (s1 - s0) / dt - (grid.F[k] @ s0 + grid.c[k])
            c = c + 0.5 * dt * u @ jnp.linalg.solve(grid.Q[k], u)
            innov = grid.y[k] - (grid.H[k] @ s0 + grid.r[k])
            c = c + 0.5 * dt * innov @ grid.Rinv[k] @ innov
        return c

    if n_int == 0:
        return cost(jnp.zeros((0,)))
    x0 = jnp.zeros((n_int * nx,))
    # quadratic -> one Newton step from zero is exact
    g = jax.grad(cost)(x0)
    Hm = jax.hessian(cost)(x0)
    xstar = -jnp.linalg.solve(Hm, g)
    return cost(xstar)


def _elem_value(e, phi, z):
    d = z - e.A @ phi - e.b
    return (0.5 * phi @ e.J @ phi - phi @ e.eta
            + 0.5 * d @ jnp.linalg.solve(e.C, d))


def test_discrete_block_element_is_exact_conditional_value():
    """block element == min over interior states of the discretised cost
    (up to the measurement-constant), for several (phi, z) pairs.

    NOTE the solvers' one-step element uses the reversed-left drift point
    (u = (z-phi)/dt - F phi - c with coefficients at the step), which the
    dense cost above replicates exactly.
    """
    model = random_ltv(jax.random.PRNGKey(0))
    T, n = 4, 5
    ts = time_grid(0.0, 1.0, T * n)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(1))
    grid = grid_lqt_from_linear(model, ts, y)
    blocks, _ = discrete_block_elements(grid, n)
    e = jax.tree_util.tree_map(lambda a: a[1], blocks)   # block 1

    rng = np.random.default_rng(2)
    vals_direct, vals_elem = [], []
    for _ in range(4):
        phi = jnp.asarray(rng.standard_normal(grid.nx))
        z = jnp.asarray(rng.standard_normal(grid.nx))
        vals_direct.append(float(_dense_conditional_min(grid, n, 2 * n,
                                                        phi, z)))
        vals_elem.append(float(_elem_value(e, phi, z)))
    # equal up to a single additive constant
    d = np.asarray(vals_direct) - np.asarray(vals_elem)
    np.testing.assert_allclose(d, d[0] * np.ones_like(d),
                               rtol=1e-6, atol=1e-6)


def test_euler_block_elements_converge_to_discrete():
    model = wiener_velocity()
    errs = []
    for T in (128, 256, 512):
        n = 10
        ts = time_grid(0.0, 5.0, T * n)
        _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
        grid = grid_lqt_from_linear(model, ts, y)
        eu = euler_block_elements(grid, n)
        di, _ = discrete_block_elements(grid, n)
        err = max(float(jnp.abs(a - b).max()) for a, b in zip(eu, di))
        errs.append(err)
    assert errs[2] < errs[1] < errs[0]


def test_one_step_element_matches_one_euler_step():
    """for n=1 the euler-ODE element IS the closed-form element."""
    model = random_ltv(jax.random.PRNGKey(5))
    ts = time_grid(0.0, 1.0, 16)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(6))
    grid = grid_lqt_from_linear(model, ts, y)
    eu = euler_block_elements(grid, 1)
    ones = one_step_elements(grid)
    for a, b in zip(eu, ones):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


def test_qp_oracle_self_consistency():
    """QP oracle from the model == QP oracle from the reversed grid."""
    from repro.core import qp_map_estimate
    model = random_ltv(jax.random.PRNGKey(8))
    ts = time_grid(0.0, 2.0, 40)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(9))
    grid = grid_lqt_from_linear(model, ts, y)
    a = qp_map_from_grid(grid)
    b = qp_map_estimate(model, ts, y)
    np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-8)
