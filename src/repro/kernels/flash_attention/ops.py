"""Jitted wrapper for the flash-attention kernel (+ ref-VJP training path)."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention
from .ref import mha_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              block_q: int = 128, block_k: int = 128,
              interpret: bool = False):
    return flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def attention_trainable(q, k, v, causal=True, window=None, interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)


def _fwd(q, k, v, causal, window, interpret):
    return attention_trainable(q, k, v, causal, window, interpret), (q, k, v)


def _bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: mha_ref(q, k, v, causal=causal, window=window),
        q, k, v)
    return vjp(g)


attention_trainable.defvjp(_fwd, _bwd)
