"""Benchmark harness entry point -- one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV lines.

  fig1/*    paper Fig. 1  (linear Wiener velocity, seq vs parallel)
  fig2/*    paper Fig. 2  (coordinated-turn iterated MAP)
  kern/*    kernel micro-benchmarks
  batch/*   request-axis throughput (problems/sec vs batch size)
  scan/*    distributed-scan span scaling (single-process proxy)

``--fast`` shrinks the sweeps (CI-sized); ``--smoke`` shrinks further to
bit-rot-check sizes (every section runs in seconds); default runs the full
grids.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: CI bit-rot check for every section")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig2,kern,batch")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    rows = []
    from benchmarks import (
        batch_throughput, fig1_linear, fig2_nonlinear, kernels_bench,
    )
    if only is None or "fig1" in only:
        if args.smoke:
            rows += fig1_linear.run(T_list=(16,), repeats=1)
        else:
            rows += fig1_linear.run(
                T_list=(128, 256) if args.fast
                else (128, 256, 512, 1024, 2048),
                repeats=3 if args.fast else 5)
    if only is None or "fig2" in only:
        if args.smoke:
            rows += fig2_nonlinear.run(T_list=(16,), repeats=1, iterations=2)
        else:
            rows += fig2_nonlinear.run(
                T_list=(64, 128) if args.fast else (64, 128, 256, 512),
                repeats=2 if args.fast else 5)
    if only is None or "kern" in only:
        rows += kernels_bench.run(smoke=args.smoke)
    if only is None or "batch" in only:
        rows += batch_throughput.run(smoke=args.smoke or args.fast)

    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
