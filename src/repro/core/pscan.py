"""Parallel associative scans: local (on-chip) and distributed (multi-chip).

The paper's span-reduction comes from ``jax.lax.associative_scan`` (Blelloch
[5]).  Orientation conventions (critical for the non-commutative operators of
``combine.py``):

* ``prefix_scan(fn, a)[i]  = a_0 (x) a_1 (x) ... (x) a_i``  (eq. 25)
* ``suffix_scan(fn, a)[i]  = a_i (x) a_{i+1} (x) ... (x) a_{T-1}``  (eq. 26)

where ``fn(x, y)`` always receives ``x`` as the EARLIER-interval operand.
``jax.lax.associative_scan(reverse=True)`` flips the sequence but keeps the
operand order, which would silently transpose non-commutative operators; the
wrappers below handle the swap explicitly and are property-tested against
sequential folds.

``distributed_scan`` shards the time axis across a mesh axis (inside
``shard_map``): local scan -> all-gather of the P per-shard carries ->
redundant small scan over carries -> local fix-up.  Work O(T/P + P) per
device, span O(log(T/P) + P) with one all-gather; this is the multi-pod
temporal decomposition described in DESIGN.md S3.
"""
from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

T = TypeVar("T")


def prefix_scan(fn: Callable[[T, T], T], elems: T, *, sequential: bool = False) -> T:
    """Inclusive prefix combine along axis 0 (earlier operand first)."""
    if sequential:
        return _sequential_prefix(fn, elems)
    return jax.lax.associative_scan(fn, elems, axis=0)


def suffix_scan(fn: Callable[[T, T], T], elems: T, *, sequential: bool = False) -> T:
    """Inclusive suffix combine along axis 0 (earlier operand first)."""
    if sequential:
        return _sequential_suffix(fn, elems)
    flipped = jax.tree_util.tree_map(lambda x: jnp.flip(x, axis=0), elems)
    swapped = lambda a, b: fn(b, a)
    out = jax.lax.associative_scan(swapped, flipped, axis=0)
    return jax.tree_util.tree_map(lambda x: jnp.flip(x, axis=0), out)


def _sequential_prefix(fn, elems):
    """O(T)-span reference fold (the paper's sequential baseline shape)."""
    first = jax.tree_util.tree_map(lambda x: x[0], elems)
    rest = jax.tree_util.tree_map(lambda x: x[1:], elems)

    def step(carry, e):
        nxt = fn(carry, e)
        return nxt, nxt

    _, tail = jax.lax.scan(step, first, rest)
    return jax.tree_util.tree_map(
        lambda f, t: jnp.concatenate([f[None], t], axis=0), first, tail
    )


def _sequential_suffix(fn, elems):
    last = jax.tree_util.tree_map(lambda x: x[-1], elems)
    rest = jax.tree_util.tree_map(lambda x: x[:-1], elems)

    def step(carry, e):
        nxt = fn(e, carry)
        return nxt, nxt

    _, head = jax.lax.scan(step, last, rest, reverse=True)
    return jax.tree_util.tree_map(
        lambda h, l: jnp.concatenate([h, l[None]], axis=0), head, last
    )


def _select_tree(pred, a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(pred, x, y), a, b
    )


def distributed_scan(
    fn: Callable[[T, T], T],
    elems: T,
    axis_name: str,
    *,
    reverse: bool = False,
) -> T:
    """Associative scan over a time axis sharded across ``axis_name``.

    Must be called INSIDE ``shard_map``; ``elems`` is the local shard with
    the local time axis at position 0.  Returns the local shard of the
    global inclusive prefix (or suffix if ``reverse``).

    No identity element is required: shard 0 (resp. the last shard for the
    reverse scan) keeps its local result via a masked select.
    """
    local = suffix_scan(fn, elems) if reverse else prefix_scan(fn, elems)
    carry = jax.tree_util.tree_map(
        lambda x: x[0] if reverse else x[-1], local
    )
    # (P, ...) per-shard totals, replicated on every shard.
    totals = jax.lax.all_gather(carry, axis_name, axis=0, tiled=False)
    idx = jax.lax.axis_index(axis_name)
    # psum of 1 == the axis size; jax.lax.axis_size is not available on
    # every supported jax release, psum works inside shard_map on all.
    p = jax.lax.psum(1, axis_name)

    if reverse:
        # exclusive suffix of totals strictly AFTER this shard
        suff = suffix_scan(fn, totals, sequential=True)
        nxt = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(
                x, jnp.minimum(idx + 1, p - 1), axis=0, keepdims=False
            ),
            suff,
        )
        # fn broadcasts the rank-reduced carry against the local time axis.
        combined = fn(local, nxt)
        return _select_tree(idx == p - 1, local, combined)

    pref = prefix_scan(fn, totals, sequential=True)
    prev = jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(
            x, jnp.maximum(idx - 1, 0), axis=0, keepdims=False
        ),
        pref,
    )
    combined = fn(prev, local)
    return _select_tree(idx == 0, local, combined)
