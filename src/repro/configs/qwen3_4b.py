"""qwen3-4b: 36L d_model=2560 32H (GQA kv=8) head_dim=128 d_ff=9728
vocab=151936, qk_norm [hf:Qwen/Qwen3 family]."""
import dataclasses

from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense",
        num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=9728, vocab_size=151936, qk_norm=True,
        rope_theta=1e6, remat_group=6)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="qwen3-4b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=128)
