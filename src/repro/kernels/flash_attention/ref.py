"""Pure-jnp oracle: causal (optionally sliding-window) GQA attention."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, *, causal: bool = True, window: int | None = None,
            scale: float | None = None):
    """Reference attention.

    Args:
      q: (B, Hq, Lq, D)
      k, v: (B, Hkv, Lk, D) with Hq % Hkv == 0 (GQA)
      causal: apply the causal mask (assumes Lq == Lk when True)
      window: sliding-window size (positions attend to the previous
        ``window-1`` positions and themselves)
      scale: logit scale; defaults to D**-0.5
    Returns:
      (B, Hq, Lq, D)
    """
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    if causal or window is not None:
        iq = jnp.arange(Lq)[:, None] + (Lk - Lq)
        jk = jnp.arange(Lk)[None, :]
        mask = jnp.ones((Lq, Lk), dtype=bool)
        if causal:
            mask &= iq >= jk
        if window is not None:
            mask &= (iq - jk) < window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), vv)
