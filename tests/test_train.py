"""Training substrate tests: checkpoint fault tolerance, data determinism,
trainer resume, loss descent on the learnable synthetic task."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.models import transformer
from repro.train import checkpoint as ckpt
from repro.train.data import LMDataPipeline
from repro.train.optimizer import (
    adamw_init, cosine_schedule, zero1_logical,
)
from repro.train.trainer import Trainer


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("smollm-135m-smoke")
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    path = ckpt.save_checkpoint(str(tmp_path), 7, (params, opt))
    assert os.path.exists(path)
    step, (p2, o2) = ckpt.restore_checkpoint(path, (params, opt))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_pruning(tmp_path):
    tree = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(str(tmp_path), s, tree)
    ckpt.prune_checkpoints(str(tmp_path), keep=2)
    left = sorted(os.listdir(tmp_path))
    assert left == ["step_000000000003.ckpt", "step_000000000004.ckpt"]
    assert ckpt.latest_checkpoint(str(tmp_path)).endswith("4.ckpt")
    # a stray tmp file must never be picked up
    open(os.path.join(tmp_path, "garbage.tmp"), "w").write("x")
    assert ckpt.latest_checkpoint(str(tmp_path)).endswith("4.ckpt")


def test_checkpoint_treedef_guard(tmp_path):
    path = ckpt.save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(path, {"b": jnp.zeros(3)})


def test_data_pipeline_deterministic_and_learnable():
    pipe = LMDataPipeline(vocab_size=64, seq_len=128, global_batch=4,
                          seed=3, period=16, corruption=0.1)
    a = pipe.batch_at(5)
    b = pipe.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch_at(6)
    assert bool(jnp.any(a["tokens"] != c["tokens"]))
    # periodic structure: token t mostly equals token t-period
    toks = np.asarray(a["tokens"])
    agree = (toks[:, 16:] == toks[:, :-16]).mean()
    assert agree > 0.75, agree


def test_trainer_runs_resumes_and_learns(tmp_path):
    cfg = get_config("smollm-135m-smoke")
    tcfg = TrainConfig(
        learning_rate=3e-3, total_steps=30, warmup_steps=3,
        checkpoint_every=10, keep_checkpoints=2, log_every=100,
        seq_len=64, global_batch=4)
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                          global_batch=4, seed=0, period=16)
    logs = []
    tr = Trainer(cfg=cfg, tcfg=tcfg, pipeline=pipe,
                 ckpt_dir=str(tmp_path), log_fn=logs.append)
    params, opt, metrics = tr.run(steps=12)
    assert int(opt.step) == 12
    loss12 = float(metrics["loss"])

    # resume: a NEW trainer picks up from the step-10 checkpoint
    tr2 = Trainer(cfg=cfg, tcfg=tcfg, pipeline=pipe,
                  ckpt_dir=str(tmp_path), log_fn=logs.append)
    params2, opt2, metrics2 = tr2.run(steps=30)
    assert int(opt2.step) == 30
    assert any("resumed" in str(l) for l in logs)
    # descent: 18 more steps must improve on the step-12 loss, and stay
    # in the vicinity of the uniform floor (longer runs dig below it --
    # see examples/train_lm.py output in EXPERIMENTS.md)
    uniform = np.log(cfg.vocab_size)
    assert float(metrics2["loss"]) < loss12, (float(metrics2["loss"]),
                                              loss12)
    assert float(metrics2["loss"]) < uniform * 1.15


def test_zero1_logical_rewrite():
    axes = ("embed", "ff")
    assert zero1_logical(axes, (512, 1024), 16) == ("zero1", "ff")
    # not divisible -> untouched
    assert zero1_logical(("embed",), (7,), 16) == ("embed",)
    # never steals a model-sharded axis
    assert zero1_logical(("vocab", "embed"), (50304, 512), 16) \
        == ("vocab", "zero1")


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                       total_steps=100)
    lr = cosine_schedule(tcfg)
    assert float(lr(0)) < float(lr(9))
    np.testing.assert_allclose(float(lr(10)), 1e-3, rtol=0.2)
    assert float(lr(99)) < 1e-4
