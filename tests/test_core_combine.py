"""Property tests for the associative operators (paper eqs. 29/42, 45-46)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AffineElement, LQTElement, ValueFn,
    affine_combine, apply_element_to_value, lqt_combine,
    prefix_scan, suffix_scan, value_as_element,
)


def _rand_psd(rng, n, scale=1.0):
    A = rng.standard_normal((n, n))
    return scale * (A @ A.T / n + 0.1 * np.eye(n))


def _rand_element(rng, n):
    return LQTElement(
        A=jnp.asarray(rng.standard_normal((n, n)) * 0.7),
        b=jnp.asarray(rng.standard_normal(n)),
        C=jnp.asarray(_rand_psd(rng, n)),
        eta=jnp.asarray(rng.standard_normal(n)),
        J=jnp.asarray(_rand_psd(rng, n)),
    )


def _elem_value(e: LQTElement, x, z):
    """Evaluate V(x; z) of eq. (41) up to its constant."""
    d = z - e.A @ x - e.b
    return (0.5 * x @ e.J @ x - x @ e.eta
            + 0.5 * d @ jnp.linalg.solve(e.C, d))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5))
def test_lqt_combine_associative(seed, n):
    rng = np.random.default_rng(seed)
    e1, e2, e3 = (_rand_element(rng, n) for _ in range(3))
    left = lqt_combine(lqt_combine(e1, e2), e3)
    right = lqt_combine(e1, lqt_combine(e2, e3))
    for a, b in zip(left, right):
        np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 4))
def test_lqt_combine_is_minplus(seed, n):
    """combine == min_z [V1(x, z) + V2(z, y)] evaluated pointwise."""
    rng = np.random.default_rng(seed)
    e1, e2 = _rand_element(rng, n), _rand_element(rng, n)
    e12 = lqt_combine(e1, e2)
    x = jnp.asarray(rng.standard_normal(n))
    y = jnp.asarray(rng.standard_normal(n))

    # analytic minimisation over z of V1(x,z)+V2(z,y):
    def total(z):
        return _elem_value(e1, x, z) + _elem_value(e2, z, y)

    zstar = jnp.linalg.solve(
        jnp.linalg.inv(e1.C) + e2.J + e2.A.T @ jnp.linalg.inv(e2.C) @ e2.A,
        jnp.linalg.inv(e1.C) @ (e1.A @ x + e1.b) + e2.eta
        + e2.A.T @ jnp.linalg.inv(e2.C) @ (y - e2.b))
    # difference of combined vs direct min must be x/y-independent (const):
    v_direct = total(zstar)
    v_comb = _elem_value(e12, x, y)
    x2 = jnp.asarray(rng.standard_normal(n))
    y2 = jnp.asarray(rng.standard_normal(n))
    zstar2 = jnp.linalg.solve(
        jnp.linalg.inv(e1.C) + e2.J + e2.A.T @ jnp.linalg.inv(e2.C) @ e2.A,
        jnp.linalg.inv(e1.C) @ (e1.A @ x2 + e1.b) + e2.eta
        + e2.A.T @ jnp.linalg.inv(e2.C) @ (y2 - e2.b))

    def total2(z):
        return _elem_value(e1, x2, z) + _elem_value(e2, z, y2)

    v_comb2 = _elem_value(e12, x2, y2)
    np.testing.assert_allclose(
        float(v_direct - v_comb), float(total2(zstar2) - v_comb2),
        rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5))
def test_affine_combine_associative(seed, n):
    rng = np.random.default_rng(seed)

    def re():
        return AffineElement(jnp.asarray(rng.standard_normal((n, n))),
                             jnp.asarray(rng.standard_normal(n)))

    e1, e2, e3 = re(), re(), re()
    l = affine_combine(affine_combine(e1, e2), e3)
    r = affine_combine(e1, affine_combine(e2, e3))
    np.testing.assert_allclose(l.Phi, r.Phi, rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(l.beta, r.beta, rtol=1e-10, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 17))
def test_scan_orientation_vs_fold(seed, T):
    """prefix/suffix scans must match sequential folds for a
    non-commutative operator (matrix product via affine_combine)."""
    rng = np.random.default_rng(seed)
    n = 3
    elems = AffineElement(
        jnp.asarray(rng.standard_normal((T, n, n))),
        jnp.asarray(rng.standard_normal((T, n))))

    pre = prefix_scan(affine_combine, elems)
    pre_ref = prefix_scan(affine_combine, elems, sequential=True)
    np.testing.assert_allclose(pre.Phi, pre_ref.Phi, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(pre.beta, pre_ref.beta, rtol=1e-9, atol=1e-9)

    suf = suffix_scan(affine_combine, elems)
    suf_ref = suffix_scan(affine_combine, elems, sequential=True)
    np.testing.assert_allclose(suf.Phi, suf_ref.Phi, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(suf.beta, suf_ref.beta, rtol=1e-9, atol=1e-9)

    # explicit fold semantics
    acc = jax.tree_util.tree_map(lambda x: x[0], elems)
    for i in range(1, T):
        acc = affine_combine(acc, jax.tree_util.tree_map(
            lambda x: x[i], elems))
    np.testing.assert_allclose(
        pre.Phi[-1], acc.Phi, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(
        suf.Phi[0], acc.Phi, rtol=1e-9, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5))
def test_value_element_embedding(seed, n):
    """combine(e, value_as_element(vf)) (J, eta) == apply_element_to_value."""
    rng = np.random.default_rng(seed)
    e = _rand_element(rng, n)
    vf = ValueFn(jnp.asarray(_rand_psd(rng, n)),
                 jnp.asarray(rng.standard_normal(n)))
    via_elem = lqt_combine(e, value_as_element(vf))
    direct = apply_element_to_value(e, vf)
    np.testing.assert_allclose(via_elem.J, direct.S, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(via_elem.eta, direct.v, rtol=1e-9, atol=1e-9)
    # the terminal element's A must be inert
    np.testing.assert_allclose(via_elem.A, np.zeros((n, n)), atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_combine_psd_preserved(seed):
    """C and J stay symmetric PSD under combination."""
    rng = np.random.default_rng(seed)
    n = 4
    e = _rand_element(rng, n)
    for _ in range(5):
        e2 = _rand_element(rng, n)
        e = lqt_combine(e, e2)
    for M in (e.C, e.J):
        np.testing.assert_allclose(M, M.T, atol=1e-9)
        w = np.linalg.eigvalsh(np.asarray(M))
        assert w.min() > -1e-8, f"lost PSD: {w}"
