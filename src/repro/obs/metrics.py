"""Process-local, thread-safe metrics registry.

Three instrument kinds, all named by dot-separated strings (taxonomy in
``docs/OBSERVABILITY.md``):

* :class:`Counter`   -- monotone int (``cache.hits``, ``engine.completed``);
* :class:`Gauge`     -- last-write-wins float (``engine.queue_depth``);
* :class:`Histogram` -- fixed geometric buckets with count/sum/min/max and
  p50/p90/p99 readout (``engine.record_latency_seconds``).

Design constraints (why this looks the way it does):

* **Zero overhead when disabled.**  Recording is gated on one module-level
  bool; every convenience helper (:func:`inc`, :func:`record`,
  :func:`set_gauge`) checks it first and returns immediately, allocating
  nothing.  The registry starts DISABLED unless the ``REPRO_OBS``
  environment variable is truthy; benchmarks and tests call
  :func:`enable` explicitly.
* **Never captures JAX tracers.**  All hot-path instrumentation lives
  OUTSIDE ``jit`` (host-side wall clocks, static shapes, cache counters).
  As a backstop, every recorded value goes through ``float()`` and values
  that refuse concretisation (abstract tracers under ``jit``/``vmap``)
  are silently dropped and tallied in ``snapshot()["dropped_records"]``
  -- instrumentation can never poison a trace or leak a tracer into host
  state.
* **Thread-safe.**  One registry lock serialises all mutation
  (``TrajectoryEngine`` submit/collect runs from client threads).

This module deliberately does not import ``jax``: it must be importable
(and near-free) in processes that never touch an accelerator.
"""
from __future__ import annotations

import bisect
import math
import os
import threading
from typing import Dict, List, Optional, Sequence


def _default_buckets() -> List[float]:
    """Geometric bucket edges covering 1e-7 .. 1e3 (3 per decade): wide
    enough for seconds-scale latencies down to sub-microsecond spans."""
    return [10.0 ** (e / 3.0) for e in range(-21, 10)]


class Counter:
    """Monotone counter.  ``inc`` only; never decreases."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock) -> None:
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += int(n)


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock) -> None:
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with percentile readout.

    Bucket ``i`` counts values in ``(edges[i-1], edges[i]]`` (bucket 0 is
    ``<= edges[0]``, the last bucket is overflow).  Percentiles are read
    back by linear interpolation across the covering bucket's edges and
    clamped to the exact observed ``[min, max]`` -- coarse by design
    (fixed memory, O(1) record) but accurate to a bucket width.
    """

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max",
                 "_lock")

    def __init__(self, name: str, lock: threading.RLock,
                 buckets: Optional[Sequence[float]] = None) -> None:
        self.name = name
        self.edges = sorted(float(b) for b in (buckets or _default_buckets()))
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = lock

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect.bisect_left(self.edges, v)] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-quantile (``0 <= q <= 1``) from the buckets."""
        with self._lock:
            if not self.count:
                return math.nan
            target = q * self.count
            seen = 0.0
            for i, c in enumerate(self.counts):
                if seen + c >= target and c:
                    lo = self.edges[i - 1] if i > 0 else min(self.min, 0.0)
                    hi = self.edges[i] if i < len(self.edges) else self.max
                    frac = (target - seen) / c
                    est = lo + frac * (hi - lo)
                    return min(max(est, self.min), self.max)
                seen += c
            return self.max

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if not self.count:
                return {"count": 0}
            return {
                "count": self.count,
                "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min,
                "max": self.max,
                "p50": self.percentile(0.50),
                "p90": self.percentile(0.90),
                "p99": self.percentile(0.99),
            }


class Registry:
    """Create-or-get store for named instruments."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self.dropped_records = 0   # tracer/NaN-refusing values, see record()

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, self._lock)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, self._lock)
            return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(
                    name, self._lock, buckets)
            return h

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self.dropped_records = 0

    def is_empty(self) -> bool:
        with self._lock:
            return not (self._counters or self._gauges or self._histograms)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": {k: c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {k: g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {k: h.summary()
                               for k, h in sorted(self._histograms.items())},
                "dropped_records": self.dropped_records,
            }


REGISTRY = Registry()

_ENABLED = os.environ.get("REPRO_OBS", "").lower() in ("1", "true", "yes")


def enable() -> None:
    """Turn recording on (process-wide)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn recording off; helpers become no-ops, nothing is allocated."""
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def reset() -> None:
    """Drop every instrument and recorded value (keeps the enabled flag)."""
    REGISTRY.reset()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str,
              buckets: Optional[Sequence[float]] = None) -> Histogram:
    return REGISTRY.histogram(name, buckets)


def _concretise(v) -> Optional[float]:
    """``float(v)`` or ``None`` for values that refuse concretisation --
    i.e. abstract JAX tracers reaching instrumentation under ``jit``.
    Dropping (instead of raising) guarantees obs can never break a trace."""
    try:
        return float(v)
    except Exception:
        with REGISTRY._lock:
            REGISTRY.dropped_records += 1
        return None


def inc(name: str, n: int = 1) -> None:
    """Increment counter ``name`` (no-op when disabled)."""
    if _ENABLED:
        REGISTRY.counter(name).inc(n)


def set_gauge(name: str, v) -> None:
    """Set gauge ``name`` (no-op when disabled; tracers dropped)."""
    if _ENABLED:
        f = _concretise(v)
        if f is not None:
            REGISTRY.gauge(name).set(f)


def record(name: str, v,
           buckets: Optional[Sequence[float]] = None) -> None:
    """Record ``v`` into histogram ``name`` (no-op when disabled; tracers
    dropped)."""
    if _ENABLED:
        f = _concretise(v)
        if f is not None:
            REGISTRY.histogram(name, buckets).record(f)
