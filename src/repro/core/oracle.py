"""Dense quadratic-program oracle for the discretised MAP problem.

The Euler-discretised (backward-Euler in original time, matching the
reversed-time solvers -- see ``sde.py`` docstring) Onsager-Machlup /
minimum-energy functional is an unconstrained convex quadratic in the
stacked trajectory ``X = (x_0, ..., x_N)``:

    M(X) = 1/2 (x_0 - m_0)^T P_0^{-1} (x_0 - m_0)
         + sum_k dt/2 || (x_{k+1}-x_k)/dt - F_k x_{k+1} - c_k ||^2_{Q_k^{-1}}
         + sum_k dt/2 || y_k - H_k x_{k+1} - r_k ||^2_{R_k^{-1}}
         (+ sum_k dt lin_k . x_{k+1})

Building the dense Hessian and solving gives the EXACT discrete MAP
trajectory -- the ground truth the scan-based solvers are tested against
(``discrete`` mode must match to round-off; ``euler`` mode to O(dt)).
Only intended for small N (tests); cost O((N nx)^3).
"""
from __future__ import annotations

import jax.numpy as jnp

from .sde import LinearSDE
from .types import GridLQT


def qp_map_estimate(model: LinearSDE, ts: jnp.ndarray, y: jnp.ndarray,
                    lin: jnp.ndarray | None = None) -> jnp.ndarray:
    F, c, H, r, Q, R = model.grids(ts)
    dt = jnp.diff(ts)
    return _qp_solve(F, c, H, r, Q, R, y, dt, model.m0, model.P0, lin)


def qp_map_from_grid(grid: GridLQT) -> jnp.ndarray:
    """Solve the QP directly from a (reversed-time) GridLQT; returns the
    trajectory in ORIGINAL time order (N+1, nx)."""
    flip = lambda a: jnp.flip(a, axis=0)
    F = -flip(grid.F)
    c = -flip(grid.c)
    H = flip(grid.H)
    r = flip(grid.r)
    Q = flip(grid.Q)
    Rinv = flip(grid.Rinv)
    y = flip(grid.y)
    dt = flip(grid.dt)
    lin = None if grid.lin is None else flip(grid.lin)
    P0 = jnp.linalg.inv(grid.S_T)
    m0 = P0 @ grid.v_T
    return _qp_solve(F, c, H, r, Q, jnp.linalg.inv(Rinv), y, dt, m0, P0, lin)


def _qp_solve(F, c, H, r, Q, R, y, dt, m0, P0, lin=None):
    # Test oracle: plain numpy (no tracing) -- the unrolled .at[] graph a
    # jnp version produces is pathologically slow to compile for large N.
    import numpy as np

    F, c, H, r, Q, R, y, dt, m0, P0 = (
        np.asarray(a, dtype=np.float64)
        for a in (F, c, H, r, Q, R, y, dt, m0, P0))
    if lin is not None:
        lin = np.asarray(lin, dtype=np.float64)
    N, nx = F.shape[0], F.shape[-1]
    n_tot = (N + 1) * nx
    Hmat = np.zeros((n_tot, n_tot))
    g = np.zeros((n_tot,))
    I = np.eye(nx)

    P0inv = np.linalg.inv(P0)
    Hmat[:nx, :nx] += P0inv
    g[:nx] += P0inv @ m0

    Qinv = np.linalg.inv(Q)
    Rinv = np.linalg.inv(R)
    for k in range(N):
        dtk = dt[k]
        # dynamics residual  D_k x_k + E_k x_{k+1} - c_k  with
        # D_k = -I/dt, E_k = I/dt - F_k (backward-Euler), weight dt * Qinv
        D = -I / dtk
        E = I / dtk - F[k]
        W = dtk * Qinv[k]
        sl0 = slice(k * nx, (k + 1) * nx)
        sl1 = slice((k + 1) * nx, (k + 2) * nx)
        Hmat[sl0, sl0] += D.T @ W @ D
        Hmat[sl0, sl1] += D.T @ W @ E
        Hmat[sl1, sl0] += E.T @ W @ D
        Hmat[sl1, sl1] += E.T @ W @ E
        g[sl0] += D.T @ W @ c[k]
        g[sl1] += E.T @ W @ c[k]
        # measurement  y_k ~ H_k x_{k+1} + r_k, weight dt * Rinv
        Wm = dtk * Rinv[k]
        Hmat[sl1, sl1] += H[k].T @ Wm @ H[k]
        g[sl1] += H[k].T @ Wm @ (y[k] - r[k])
        if lin is not None:
            g[sl1] += -dtk * lin[k]

    X = np.linalg.solve(Hmat, g)
    return jnp.asarray(X.reshape(N + 1, nx))
