"""Temporal parallelism across devices: the paper's scan, sharded in time.

Forces 8 host devices and solves one T=512-block MAP problem through the
PUBLIC estimation surface with ``method="distributed"`` -- the solver
shards both global associative scans over the mesh's time axis (local
Blelloch scan + one all-gather of carries + redundant carry scan + local
fix-up; the multi-pod decomposition of DESIGN.md S3).  Verifies exact
agreement with the single-device ``parallel_rts`` method.

    PYTHONPATH=src python examples/distributed_scan_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.configs.wiener_velocity import WienerVelocityConfig
from repro.core import (
    DistributedOptions, Estimator, ParallelOptions, Problem,
    simulate_linear, time_grid,
)
from repro.distributed import MeshSpec

cfg = WienerVelocityConfig(p0=1.0)
model = cfg.model()
T, n = 512, 10
ts = time_grid(cfg.t0, cfg.tf, T * n)
_, y = simulate_linear(model, ts, jax.random.PRNGKey(0))
problem = Problem.single(model, ts, y)

# One mesh entry point: MeshSpec describes the (time x batch) layout and
# is passed wherever a mesh= is accepted (or entered via .activate()).
mesh = MeshSpec(time=8)

dist = Estimator(model, method="distributed", mesh=mesh,
                 options=DistributedOptions(nsub=n, mode="discrete"))
single = Estimator(model, method="parallel_rts",
                   options=ParallelOptions(nsub=n, mode="discrete"))

sol_dist = dist.solve(problem)
sol_single = single.solve(problem)
gap = max(float(jnp.abs(sol_dist.x - sol_single.x).max()),
          float(jnp.abs(sol_dist.S - sol_single.S).max()))

print(f"devices           : {jax.device_count()}")
print(f"time blocks       : {T} ({T // 8} per device)")
print(f"distributed vs single-device parallel max gap: {gap:.2e}")
print("filter info at t_f (diag):",
      jnp.diagonal(sol_dist.S[-1]).round(3))
assert gap < 1e-8
print("OK")
