"""The ``repro.linearize`` subsystem: sigma-point generators, SLR, the
Taylor extraction (bit-exact with the pre-subsystem path), and
``method="sigma_point"`` behind ``Estimator.solve`` across layouts and
inner solvers.  Deterministic counterparts of the hypothesis suite in
``test_linearize_properties.py`` (which needs hypothesis installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.core import (
    Estimator,
    IteratedOptions,
    ParallelOptions,
    Problem,
    SequentialOptions,
    SigmaPointOptions,
    get_method,
    iterated_solve,
    method_names,
    simulate_nonlinear,
    time_grid,
)
from repro.core.sde import grid_lqt_from_nonlinear
from repro.linearize import (
    SLR,
    Cubature,
    GaussHermite,
    Linearization,
    Taylor,
    Unscented,
    cubature,
    gauss_hermite,
    get_linearization,
    linearization_names,
    unit_points,
    unscented,
)

from helpers import coordinated_turn

FAMILIES = [Unscented(), Unscented(alpha=0.5, kappa=3.0),
            Cubature(), GaussHermite(order=3), GaussHermite(order=5)]


@pytest.fixture(scope="module")
def ct_problem():
    model = coordinated_turn()
    N = 200
    ts = time_grid(0.0, 5.0, N)
    xs, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(2))
    return model, ts, xs, y


# ---------------------------------------------------------------------------
# sigma-point generators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES, ids=str)
@pytest.mark.parametrize("n", [1, 2, 5])
def test_weights_sum_to_one(family, n):
    pts = unit_points(family, n)
    assert pts.points.shape == (family.num_points(n), n)
    np.testing.assert_allclose(np.sum(pts.wm), 1.0, rtol=0, atol=1e-13)


@pytest.mark.parametrize("family", FAMILIES, ids=str)
@pytest.mark.parametrize("n", [1, 2, 5])
def test_points_reproduce_mean_and_cov(family, n):
    """Quadrature of x and x x^T over the unit points recovers the
    standard normal's moments (0, I) to machine precision."""
    pts = unit_points(family, n)
    mean = pts.wm @ pts.points
    np.testing.assert_allclose(mean, np.zeros(n), rtol=0, atol=1e-12)
    cov = np.einsum("s,si,sj->ij", pts.wc, pts.points, pts.points)
    np.testing.assert_allclose(cov, np.eye(n), rtol=0, atol=1e-12)


def test_generation_is_cached():
    assert unit_points(Cubature(), 4) is unit_points(Cubature(), 4)


def test_unscented_validates():
    with pytest.raises(ValueError, match="alpha"):
        Unscented(alpha=0.0)
    with pytest.raises(ValueError, match="lambda"):
        unit_points(Unscented(alpha=1.0, kappa=-7.0), 5)
    with pytest.raises(ValueError, match="order"):
        GaussHermite(order=0)
    with pytest.raises(ValueError, match="order"):
        GaussHermite(order=1)     # one midpoint: no covariance to regress on
    with pytest.raises(ValueError, match="points"):
        unit_points(GaussHermite(order=9), 7)


# ---------------------------------------------------------------------------
# SLR regression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES, ids=str)
def test_slr_recovers_affine_exactly(family):
    """SLR of an affine function returns (A, b) exactly and Omega == 0 --
    the property making SLR == Taylor on linear models."""
    rng = np.random.default_rng(3)
    A_true = jnp.asarray(rng.standard_normal((3, 4)))
    b_true = jnp.asarray(rng.standard_normal(3))
    cov = jnp.asarray(np.diag(rng.uniform(0.5, 2.0, 4)))
    m = jnp.asarray(rng.standard_normal(4))

    def g(x, t):
        return A_true @ x + b_true

    lin = SLR(family)
    A, b, Omega = lin(g, m, 0.0, cov)
    np.testing.assert_allclose(A, A_true, rtol=0, atol=1e-11)
    np.testing.assert_allclose(b, b_true, rtol=0, atol=1e-11)
    np.testing.assert_allclose(Omega, np.zeros((3, 3)), rtol=0, atol=1e-11)


def test_slr_equals_taylor_on_linear_grid(ct_problem):
    """On a linearised-in-x model the SLR grid build matches the Taylor
    grid build (Omega == 0 folds in nothing)."""
    from repro.core import NonlinearSDE

    model, ts, _, y = ct_problem
    F = jnp.asarray(np.diag([0.9, 0.8, 1.1, 1.0, 0.95]))

    lin_model = NonlinearSDE(
        f=lambda x, t: F @ x, h=lambda x, t: x[:2],
        Q=jnp.eye(5) * 1e-3, R=jnp.eye(2) * 1e-2,
        m0=model.m0, P0=model.P0)
    xbar = jnp.broadcast_to(lin_model.m0, (y.shape[0] + 1, 5))
    g_t = grid_lqt_from_nonlinear(lin_model, ts, y, xbar,
                                  linearization="taylor")
    g_s = grid_lqt_from_nonlinear(lin_model, ts, y, xbar,
                                  linearization="cubature")
    np.testing.assert_allclose(g_s.F, g_t.F, rtol=0, atol=1e-10)
    np.testing.assert_allclose(g_s.c, g_t.c, rtol=0, atol=1e-10)
    np.testing.assert_allclose(g_s.H, g_t.H, rtol=0, atol=1e-10)
    np.testing.assert_allclose(g_s.r, g_t.r, rtol=0, atol=1e-10)
    np.testing.assert_allclose(g_s.Q, g_t.Q, rtol=1e-10, atol=1e-14)
    np.testing.assert_allclose(g_s.Rinv, g_t.Rinv, rtol=1e-10, atol=1e-8)


def test_slr_requires_cov():
    lin = cubature()
    with pytest.raises(ValueError, match="spread covariance"):
        lin(lambda x, t: x, jnp.zeros(2), 0.0)


def test_slr_is_jit_and_vmap_safe():
    lin = unscented()

    def g(x, t):
        return jnp.sin(x) * (1.0 + t)

    xb = jnp.asarray(np.random.default_rng(0).standard_normal((7, 3)))
    tl = jnp.linspace(0.0, 1.0, 7)
    covs = jnp.broadcast_to(jnp.eye(3), (7, 3, 3))
    eager = lin.linearize_grid(g, xb, tl, covs)
    jitted = jax.jit(lambda x, t, c: lin.linearize_grid(g, x, t, c))(
        xb, tl, covs)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# registry / options plumbing
# ---------------------------------------------------------------------------


def test_registry_contents():
    names = linearization_names()
    for expected in ("taylor", "unscented", "cubature", "gauss_hermite"):
        assert expected in names
    assert isinstance(get_linearization(None), Taylor)
    assert isinstance(get_linearization("unscented"), SLR)
    inst = gauss_hermite(order=5)
    assert get_linearization(inst) is inst
    with pytest.raises(ValueError, match="linearization must be one of"):
        get_linearization("nope")
    with pytest.raises(TypeError, match="str or Linearization"):
        get_linearization(42)


def test_iterated_options_resolve_linearization():
    o = IteratedOptions()
    assert isinstance(o.linearization, Taylor)
    o = IteratedOptions(linearization="cubature")
    assert isinstance(o.linearization, SLR)
    assert isinstance(o.linearization.family, Cubature)
    with pytest.raises(ValueError, match="linearization must be one of"):
        IteratedOptions(linearization="bogus")
    # options stay hashable (executable-cache key material)
    assert hash(o) == hash(IteratedOptions(linearization=cubature()))


def test_sigma_point_options_validate():
    o = SigmaPointOptions()
    assert isinstance(o.linearization, SLR)
    assert isinstance(o.linearization.family, Unscented)
    assert o.inner_method == "parallel_rts"
    with pytest.raises(ValueError, match="inner_method"):
        SigmaPointOptions(inner_method="")
    with pytest.raises(ValueError, match="method must be one of"):
        Estimator(coordinated_turn(), method="sigma_point",
                  options=SigmaPointOptions(inner_method="bogus"))


def test_sigma_point_method_registered():
    assert "sigma_point" in method_names()
    spec = get_method("sigma_point")
    assert spec.nonlinear
    assert not get_method("parallel_rts").nonlinear
    with pytest.raises(TypeError, match="not a grid\\s+solver"):
        spec.solver(None, SigmaPointOptions())


def test_sigma_point_requires_nonlinear_model():
    from repro.core import LinearSDE

    model = LinearSDE(F=jnp.zeros((2, 2)), c=jnp.zeros(2),
                      H=jnp.eye(2), r=jnp.zeros(2),
                      Q=jnp.eye(2), R=jnp.eye(2),
                      m0=jnp.zeros(2), P0=jnp.eye(2))
    with pytest.raises(TypeError, match="NonlinearSDE"):
        Estimator(model, method="sigma_point")


def test_sigma_point_options_rejected_by_linear_methods():
    model = coordinated_turn()
    with pytest.raises(TypeError, match="sigma_point"):
        Estimator(model, method="parallel_rts",
                  options=SigmaPointOptions())


def test_nested_nonlinear_inner_method_rejected():
    model = coordinated_turn()
    with pytest.raises(ValueError, match="itself an"):
        Estimator(model, method="sigma_point",
                  options=SigmaPointOptions(inner_method="sigma_point"))


# ---------------------------------------------------------------------------
# Taylor extraction: bit-exact regression
# ---------------------------------------------------------------------------


def test_taylor_default_is_bit_exact(ct_problem):
    """IteratedOptions(linearization='taylor') (and the default) produce
    the identical computation graph as before the subsystem existed: the
    two Estimator paths agree to 0 ULP."""
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    inner = ParallelOptions(nsub=10, mode="discrete")
    default = Estimator(model, method="parallel_rts",
                        options=IteratedOptions(inner=inner)).solve(problem)
    explicit = Estimator(
        model, method="parallel_rts",
        options=IteratedOptions(inner=inner,
                                linearization="taylor")).solve(problem)
    np.testing.assert_array_equal(np.asarray(default.x),
                                  np.asarray(explicit.x))
    np.testing.assert_array_equal(np.asarray(default.cost_trace),
                                  np.asarray(explicit.cost_trace))
    # and the engine-room entry point agrees with the Estimator surface
    spec = get_method("parallel_rts")
    sol, trace, _ = jax.jit(
        lambda t, yy: iterated_solve(
            model, t, yy, lambda g: spec.solver(g, inner),
            iterations=5, linearization=Taylor()))(ts, y)
    np.testing.assert_array_equal(np.asarray(default.x), np.asarray(sol.x))


def test_sigma_point_with_taylor_equals_ieks(ct_problem):
    """method='sigma_point' with linearization='taylor' IS the plain
    IEKS -- same grids, same inner solver, same result."""
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    inner = ParallelOptions(nsub=10, mode="discrete")
    ieks = Estimator(model, method="parallel_rts",
                     options=IteratedOptions(inner=inner)).solve(problem)
    sp = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(linearization="taylor",
                                  inner=inner)).solve(problem)
    np.testing.assert_array_equal(np.asarray(ieks.x), np.asarray(sp.x))


# ---------------------------------------------------------------------------
# method="sigma_point" end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lin", ["unscented", "cubature"])
def test_sigma_point_cost_not_worse_than_taylor(ct_problem, lin):
    """Acceptance: on the coordinated-turn model the posterior-
    linearisation smoother reaches a final OM cost <= the Taylor IEKS at
    the same iteration count (tiny float slack)."""
    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    inner = ParallelOptions(nsub=10, mode="discrete")
    tay = Estimator(model, method="parallel_rts",
                    options=IteratedOptions(inner=inner,
                                            iterations=5)).solve(problem)
    sp = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(linearization=lin, inner=inner,
                                  iterations=5)).solve(problem)
    t_cost, s_cost = float(tay.cost), float(sp.cost)
    assert s_cost <= t_cost * (1 + 1e-6), (s_cost, t_cost)


@pytest.mark.parametrize("inner_method,inner", [
    ("parallel_rts", ParallelOptions(nsub=10, mode="discrete")),
    ("sequential_rts", SequentialOptions(mode="discrete")),
])
def test_sigma_point_inner_solvers_agree(ct_problem, inner_method, inner):
    model, ts, _, y = ct_problem
    sol = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(inner_method=inner_method,
                                  inner=inner)).solve(
        Problem.single(model, ts, y))
    assert np.all(np.isfinite(np.asarray(sol.x)))
    ref = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(inner=ParallelOptions(
            nsub=10, mode="discrete"))).solve(Problem.single(model, ts, y))
    np.testing.assert_allclose(sol.x, ref.x, rtol=1e-7, atol=1e-7)


def test_sigma_point_distributed_inner_fallback(ct_problem):
    """inner_method='distributed' on one device degrades to the parallel
    scan (fallback='auto') and matches the parallel_rts inner."""
    from repro.core import DistributedOptions

    model, ts, _, y = ct_problem
    problem = Problem.single(model, ts, y)
    dist = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(
            inner_method="distributed",
            inner=DistributedOptions(nsub=10, mode="discrete"))).solve(
        problem)
    ref = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(inner=ParallelOptions(
            nsub=10, mode="discrete"))).solve(problem)
    np.testing.assert_allclose(dist.x, ref.x, rtol=1e-10, atol=1e-10)


def test_sigma_point_stacked_and_masked(ct_problem):
    """Stacked layout with a per-record mask: each batch row equals its
    single-record solve (vmap consistency of the SLR path)."""
    model, ts, _, y = ct_problem
    N = y.shape[0]
    y2 = jnp.stack([y, y[::-1]])
    mask = jnp.ones((2, N)).at[1, N // 2:].set(0.0)
    opts = SigmaPointOptions(inner=ParallelOptions(nsub=10,
                                                   mode="discrete"))
    est = Estimator(model, method="sigma_point", options=opts)
    batch = est.solve(Problem.stacked(model, ts, y2,
                                      measurement_mask=mask))
    for b in range(2):
        single = est.solve(Problem.single(model, ts, y2[b],
                                          measurement_mask=mask[b]))
        np.testing.assert_allclose(batch.x[b], single.x,
                                   rtol=1e-9, atol=1e-9)


def test_sigma_point_ragged(ct_problem):
    model, ts, _, y = ct_problem
    recs = [(ts[:101], y[:100]), (ts[:151], y[:150])]
    est = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(inner=ParallelOptions(
            nsub=10, mode="discrete")))
    sols = est.solve(Problem.ragged(model, recs))
    assert len(sols) == 2
    for (ts_i, y_i), sol in zip(recs, sols):
        assert sol.x.shape == (y_i.shape[0] + 1, model.nx)
        assert np.all(np.isfinite(np.asarray(sol.x)))


def test_sigma_point_warm_start(ct_problem):
    """x_init warm-start (the streaming handoff) composes with SLR."""
    model, ts, _, y = ct_problem
    est = Estimator(
        model, method="sigma_point",
        options=SigmaPointOptions(inner=ParallelOptions(
            nsub=10, mode="discrete")))
    cold = est.solve(Problem.single(model, ts, y))
    warm = est.solve(Problem.single(model, ts, y,
                                    x_init=cold.x))
    np.testing.assert_allclose(warm.x, cold.x, rtol=1e-6, atol=1e-6)


def test_streaming_engine_sigma_point(ct_problem):
    """StreamingEngine accepts method='sigma_point' (nonlinear windows
    carry the linearisation choice through robust_default_options)."""
    from repro.serving import StreamingEngine
    from repro.serving.waves import robust_default_options

    opts = robust_default_options("sigma_point")
    assert isinstance(opts, SigmaPointOptions)
    assert opts.inner.mode == "discrete"

    model, ts, _, y = ct_problem
    eng = StreamingEngine(model, lag=8, batch=2, method="sigma_point")
    tid = eng.open_track(float(ts[0]))
    eng.push(tid, np.asarray(ts[1:41]), np.asarray(y[:40]))
    eng.run()
    sol = eng.estimate(tid)
    assert sol.x.shape == (41, model.nx)
    assert np.all(np.isfinite(np.asarray(sol.x)))


def test_linearize_obs_counters(ct_problem):
    from repro.core import ExecutableCache

    model, ts, _, y = ct_problem
    obs.enable()
    try:
        obs.reset()
        # private cache: the trace-time slr counters fire on compilation,
        # so the executable must not be reused from an earlier test
        est = Estimator(
            model, method="sigma_point",
            options=SigmaPointOptions(inner=ParallelOptions(
                nsub=10, mode="discrete")),
            cache=ExecutableCache())
        est.solve(Problem.single(model, ts, y))
        snap = obs.snapshot()
        counters = snap["counters"]
        assert counters.get("linearize.unscented.solves", 0) >= 1
        assert counters.get("linearize.slr.regressions", 0) >= y.shape[0]
        assert snap["gauges"]["linearize.sigma_points"] == 2 * model.nx + 1
    finally:
        obs.disable()
        obs.reset()
