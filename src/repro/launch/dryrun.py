import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analyses.

MUST be executed as its own process (``python -m repro.launch.dryrun``):
the first two lines force 512 host platform devices BEFORE any jax import,
which is why nothing above them may import repro or jax.

Per cell:
  * build the step function (train_step / prefill_step / serve_step),
  * derive in/out NamedShardings from the logical axes,
  * ``jax.jit(step, ...).lower(**input_specs).compile()``,
  * record ``compiled.memory_analysis()``, ``compiled.cost_analysis()``
    and the per-collective byte totals parsed from the post-optimisation
    HLO (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) into artifacts/dryrun/<mesh>/<arch>/<shape>.json.

Usage:
  python -m repro.launch.dryrun --arch mamba2-370m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--cells a,b,...]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_bytes(header: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(header):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def _split_computations(hlo_text: str) -> dict:
    """computation name -> list of body lines (post-optimisation HLO)."""
    comps = {}
    cur, buf = None, []
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\-\.]+)\s*(?:\(.*)?\{")
    for line in hlo_text.splitlines():
        if cur is None:
            m = header_re.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur, buf = m.group(1), []
                if "ENTRY" in line:
                    cur = "__entry__"
        else:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line.strip())
    return comps


def _collective_bytes(hlo_text: str) -> dict:
    """Loop-aware collective accounting.

    XLA while-loop bodies execute trip-count times but appear once in the
    text, so naive per-line sums undercount collectives inside the layer
    scan.  This walks the computation graph: per-computation collective
    bytes, while-op (condition, body) edges with trip counts recovered
    from the condition's loop-bound constant, recursively multiplied.
    """
    comps = _split_computations(hlo_text)
    own = {name: {k: 0 for k in _COLL_KINDS} for name in comps}
    own_counts = {name: {k: 0 for k in _COLL_KINDS} for name in comps}
    whiles = {name: [] for name in comps}   # (cond, body) per while op
    while_re = re.compile(
        r"condition=%?([\w\-\.]+).*body=%?([\w\-\.]+)")

    for name, lines in comps.items():
        for s in lines:
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", s)
            if not m:
                continue
            rhs = m.group(1)
            if " while(" in rhs or rhs.startswith("while("):
                wm = while_re.search(rhs)
                if wm:
                    whiles[name].append((wm.group(1), wm.group(2)))
                continue
            for k in _COLL_KINDS:
                if re.search(rf"\b{k}(-start)?\(", rhs):
                    paren = rhs.find("(")
                    own[name][k] += _line_bytes(rhs[:paren])
                    own_counts[name][k] += 1
                    break

    def trip_count(cond_name: str) -> int:
        best = 1
        for s in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", s):
                best = max(best, int(m.group(1)))
        return best

    import functools as _ft

    @_ft.lru_cache(maxsize=None)
    def total(name: str) -> tuple:
        t = dict(own.get(name, {k: 0 for k in _COLL_KINDS}))
        c = dict(own_counts.get(name, {k: 0 for k in _COLL_KINDS}))
        for cond, body in whiles.get(name, []):
            n = trip_count(cond)
            bt, bc = total(body)
            for k in _COLL_KINDS:
                t[k] += n * bt[k]
                c[k] += n * bc[k]
        return t, c

    entry = "__entry__" if "__entry__" in comps else (
        next(iter(comps)) if comps else "")
    tot, counts = total(entry) if entry else (
        {k: 0 for k in _COLL_KINDS}, {k: 0 for k in _COLL_KINDS})
    flat = {name: sum(v.values()) for name, v in own.items()
            if sum(v.values())}
    return {
        "bytes": {k: int(v) for k, v in tot.items()},
        "counts": {k: int(v) for k, v in counts.items()},
        "total_bytes": int(sum(tot.values())),
        "naive_bytes": int(sum(sum(v.values()) for v in own.values())),
        "per_computation_naive": flat,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, model_kw: dict | None = None,
             tag: str = "", overrides: dict | None = None,
             microbatches: int | None = None) -> dict:
    from repro.config import (
        SHAPE_SUITE, TrainConfig, get_config, shape_skip_reason)
    from repro.distributed.sharding import (
        choose_pspec, mesh_context, tree_shardings)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import cache_pspecs, make_step
    from repro.train.trainer import make_shardings
    from jax.sharding import NamedSharding, PartitionSpec as P

    import dataclasses

    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            if isinstance(cur, bool):
                typed[k] = str(v).lower() in ("1", "true", "yes")
            elif isinstance(cur, int):
                typed[k] = int(v)
            elif isinstance(cur, float):
                typed[k] = float(v)
            else:
                typed[k] = v
        cfg = dataclasses.replace(cfg, **typed)
    shape = next(s for s in SHAPE_SUITE if s.name == shape_name)
    mesh_name = "pod512" if multi_pod else "pod256"
    record_overrides = dict(overrides or {})
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tag": tag,
        "overrides": record_overrides,
    }
    reason = shape_skip_reason(cfg, shape)
    if reason:
        record["status"] = "skipped"
        record["skip_reason"] = reason
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    # microbatched gradient accumulation keeps activations on-chip (8 x
    # 512-token microbatches per step at train_4k); see EXPERIMENTS.md
    # SPerf iteration 0.  dp-only uses microbatches=1 (per-device batch
    # is already a single sequence).
    dp_only = cfg.parallel_policy == "dp_only"
    default_mb = 8 if (shape.kind == "train" and not dp_only) else 1
    tcfg = TrainConfig(
        zero1=True,
        microbatches=microbatches or default_mb)
    ctx_kw = {}
    if dp_only:
        from repro.distributed.sharding import MODEL_PRIORITY
        ctx_kw = dict(batch_axes=("pod", "data", "model"),
                      tp_exclude=frozenset(MODEL_PRIORITY)
                      - {"vocab", "embed_model"})
    t0 = time.time()
    try:
        with mesh_context(mesh, **ctx_kw):
            step_fn, specs = make_step(cfg, shape, tcfg,
                                       **(model_kw or {}))
            p_shard, o_shard = make_shardings(cfg, tcfg, mesh)

            def b_shard(spec_tree):
                return jax.tree_util.tree_map(
                    lambda s: NamedSharding(
                        mesh, choose_pspec(
                            s.shape, ("batch",) + (None,) * (len(s.shape) - 1),
                            mesh)),
                    spec_tree)

            cache_sh = jax.tree_util.tree_map(
                lambda p: NamedSharding(mesh, p),
                cache_pspecs(cfg, mesh, shape.global_batch),
                is_leaf=lambda x: isinstance(x, P))
            out_sh = None
            if shape.kind == "train":
                in_sh = (p_shard, o_shard, b_shard(specs["batch"]))
                args = (specs["params"], specs["opt"], specs["batch"])
                out_sh = (p_shard, o_shard, None)
            elif shape.kind == "prefill":
                in_sh = (p_shard, b_shard(specs["batch"]))
                args = (specs["params"], specs["batch"])
                out_sh = (None, cache_sh)
            else:
                in_sh = (p_shard,
                         b_shard(specs["tokens"]),
                         cache_sh)
                args = (specs["params"], specs["tokens"], specs["caches"])
                out_sh = (None, cache_sh)

            donate = (0, 1) if shape.kind == "train" else ()
            jitted = jax.jit(step_fn, in_shardings=in_sh,
                             out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # older jax returns a per-computation list of dicts
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            coll = _collective_bytes(hlo)
            # archive the optimised HLO for offline re-analysis
            try:
                import zstandard as zstd
                hdir = os.path.join(os.path.dirname(out_dir), "hlo")
                os.makedirs(hdir, exist_ok=True)
                tagpart = f"-{tag}" if tag else ""
                hpath = os.path.join(
                    hdir, f"{mesh_name}--{arch}--{shape_name}{tagpart}"
                          ".hlo.zst")
                with open(hpath, "wb") as f:
                    f.write(zstd.ZstdCompressor(level=9).compress(
                        hlo.encode()))
                record["hlo_path"] = hpath
            except Exception:
                pass

        record.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes",
                          "output_size_in_bytes",
                          "temp_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            "cost_analysis": {
                k: float(v) for k, v in (cost or {}).items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals")
                    or k.startswith("bytes accessed"))
            },
            "collectives": coll,
            "num_devices": mesh.devices.size,
        })
    except Exception as e:  # record the failure; the suite reports it
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        # XLA SPMD has a verifier bug with the microbatch scan over
        # odd-vocab embed-sharded models (hymba: vocab 32001); retry the
        # cell unmicrobatched before reporting failure.
        if (shape.kind == "train" and tcfg.microbatches > 1
                and microbatches is None):
            retry = run_cell(arch, shape_name, multi_pod, out_dir,
                             model_kw=model_kw, tag=tag,
                             overrides=overrides, microbatches=1)
            if retry.get("status") == "ok":
                retry["note"] = ("microbatches=1 fallback (XLA SPMD "
                                 "verifier bug at microbatches=8)")
                return retry
    return record


def _write(record, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"-{record['tag']}" if record.get("tag") else ""
    path = os.path.join(
        out_dir,
        f"{record['mesh']}--{record['arch']}--{record['shape']}{tag}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--causal-skip", action="store_true",
                    help="triangular causal schedule (perf variant)")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (repeatable), "
                         "e.g. --set seq_parallel=true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in getattr(args, "set"))

    from repro.config import SHAPE_SUITE
    from repro.configs import ARCHS

    cells = []
    if args.all:
        for arch in ARCHS:
            for s in SHAPE_SUITE:
                cells.append((arch, s.name))
    else:
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    model_kw = {"causal_skip": True} if args.causal_skip else None
    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, multi_pod, args.out,
                           model_kw=model_kw, tag=args.tag,
                           overrides=overrides,
                           microbatches=args.microbatches)
            path = _write(rec, args.out)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f" flops={rec['cost_analysis'].get('flops', 0):.3g}"
                         f" coll={rec['collectives']['total_bytes']:.3g}B"
                         f" compile={rec['compile_s']}s")
            elif status == "failed":
                failures += 1
                extra = " " + rec["error"][:160]
            print(f"[dryrun] {rec['mesh']} {arch} {shape}: "
                  f"{status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
