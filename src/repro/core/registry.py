"""Method registry: one dispatch table for every MAP solver backend.

``api.map_estimate`` and ``nonlinear.iterated_map`` used to carry parallel
if-chains over method names; both now dispatch through this table, and new
backends (e.g. a kernel-backed combine, a distributed-scan variant) plug in
with :func:`register_method` without touching the call sites.

Every solver is normalised to the uniform signature

    solver(grid: GridLQT, nsub: int, mode: str) -> MAPSolution

(sequential methods simply ignore ``nsub``).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from .parallel import parallel_rts, parallel_two_filter
from .sequential import sequential_rts, sequential_two_filter
from .types import GridLQT, MAPSolution

Solver = Callable[[GridLQT, int, str], MAPSolution]

_SOLVERS: Dict[str, Solver] = {}


def register_method(name: str, solver: Solver, *, overwrite: bool = False) -> None:
    """Register a solver backend under ``name``.

    ``solver`` must accept ``(grid, nsub, mode)`` and return a
    :class:`~repro.core.types.MAPSolution`.
    """
    if name in _SOLVERS and not overwrite:
        raise ValueError(f"method {name!r} already registered")
    _SOLVERS[name] = solver


def get_solver(name: str) -> Solver:
    try:
        return _SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {method_names()}, got {name!r}"
        ) from None


def method_names() -> Tuple[str, ...]:
    return tuple(_SOLVERS)


# parallel solvers already have the registry signature; the sequential
# ones take no nsub and need the dropping adapter.
register_method("parallel_rts", parallel_rts)
register_method("parallel_two_filter", parallel_two_filter)
register_method("sequential_rts",
                lambda grid, nsub, mode: sequential_rts(grid, mode))
register_method("sequential_two_filter",
                lambda grid, nsub, mode: sequential_two_filter(grid, mode))
