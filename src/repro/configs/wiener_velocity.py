"""Paper section 5.1: the partially observed Wiener velocity model
(eqs. 52-54) -- the linear experiment behind Fig. 1."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import LinearSDE


@dataclasses.dataclass(frozen=True)
class WienerVelocityConfig:
    t0: float = 0.0
    tf: float = 5.0
    q: float = 4.0           # W = q I2 (paper: 4)
    r: float = 1e-2          # R = r I2
    p0: float = 1e-2         # P0 = p0 I4 (paper; stiff for explicit Euler
                             # unless dt < ~2.5e-3, see DESIGN.md S6)
    nsub: int = 10           # paper: n = 10 substeps per block
    q_jitter: float = 0.0    # solvers never invert Q; keep it singular

    def model(self) -> LinearSDE:
        F = jnp.block([[jnp.zeros((2, 2)), jnp.eye(2)],
                       [jnp.zeros((2, 4))]])
        H = jnp.concatenate([jnp.eye(2), jnp.zeros((2, 2))], axis=1)
        L = jnp.concatenate([jnp.zeros((2, 2)), jnp.eye(2)], axis=0)
        Q = L @ (self.q * jnp.eye(2)) @ L.T
        if self.q_jitter:
            Q = Q + self.q_jitter * jnp.eye(4)
        return LinearSDE(
            F=F, c=jnp.zeros(4), H=H, r=jnp.zeros(2), Q=Q,
            R=self.r * jnp.eye(2),
            m0=jnp.array([5.0, 5.0, 0.0, 0.0]),
            P0=self.p0 * jnp.eye(4))


def config() -> WienerVelocityConfig:
    return WienerVelocityConfig()
