"""Structural, loop-aware HLO collective analysis with wire-byte costs.

Shared by the dry-run (quick totals) and the roofline (wire-byte refined,
re-parsed from the archived ``artifacts/hlo/*.hlo.zst``).

Wire bytes per device for a collective whose HLO OUTPUT is ``out`` bytes
within a replica group of size ``g`` (ring algorithms):

  all-gather          out * (g-1)/g         (output = gathered size)
  reduce-scatter      out * (g-1)            (output = scattered shard)
  all-reduce          out * 2(g-1)/g         (RS + AG)
  all-to-all          out * (g-1)/g
  collective-permute  out                    (point-to-point)

``while``-loop bodies appear once in the text but run trip-count times;
the walk multiplies nested bodies by trip counts recovered from the loop
condition's bound constant (scan trip counts are compile-time constants).
"""
from __future__ import annotations

import functools
import re
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(r"condition=%?([\w\-\.]+).*body=%?([\w\-\.]+)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(header: str) -> int:
    n_total = 0
    for dt, dims in _SHAPE_RE.findall(header):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n * DTYPE_BYTES[dt]
    return n_total


def group_size(rhs: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(rhs)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def wire_bytes(kind: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-reduce":
        return out_bytes * 2 * (g - 1) / g
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur, buf = None, []
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\-\.]+)\s*(?:\(.*)?\{")
    for line in hlo_text.splitlines():
        if cur is None:
            m = header_re.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur, buf = m.group(1), []
                if "ENTRY" in line:
                    cur = "__entry__"
        else:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line.strip())
    return comps


def collective_analysis(hlo_text: str) -> dict:
    """Loop-aware totals: raw output bytes AND wire bytes per kind."""
    comps = split_computations(hlo_text)
    own_out = {n: {k: 0.0 for k in COLL_KINDS} for n in comps}
    own_wire = {n: {k: 0.0 for k in COLL_KINDS} for n in comps}
    own_cnt = {n: {k: 0 for k in COLL_KINDS} for n in comps}
    whiles: Dict[str, List[Tuple[str, str]]] = {n: [] for n in comps}

    for name, lines in comps.items():
        for s in lines:
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", s)
            if not m:
                continue
            rhs = m.group(1)
            if " while(" in rhs or rhs.startswith("while("):
                wm = _WHILE_RE.search(rhs)
                if wm:
                    whiles[name].append((wm.group(1), wm.group(2)))
                continue
            for k in COLL_KINDS:
                if re.search(rf"\b{k}(-start)?\(", rhs):
                    out_b = shape_bytes(rhs[:rhs.find("(")])
                    g = group_size(rhs)
                    own_out[name][k] += out_b
                    own_wire[name][k] += wire_bytes(k, out_b, g)
                    own_cnt[name][k] += 1
                    break

    def trip_count(cond: str) -> int:
        best = 1
        for s in comps.get(cond, []):
            for m in re.finditer(r"constant\((\d+)\)", s):
                best = max(best, int(m.group(1)))
        return best

    @functools.lru_cache(maxsize=None)
    def total(name: str):
        o = dict(own_out.get(name, {k: 0.0 for k in COLL_KINDS}))
        w = dict(own_wire.get(name, {k: 0.0 for k in COLL_KINDS}))
        c = dict(own_cnt.get(name, {k: 0 for k in COLL_KINDS}))
        for cond, body in whiles.get(name, []):
            n = trip_count(cond)
            bo, bw, bc = total(body)
            for k in COLL_KINDS:
                o[k] += n * bo[k]
                w[k] += n * bw[k]
                c[k] += n * bc[k]
        return o, w, c

    entry = "__entry__" if "__entry__" in comps else ""
    if entry:
        out, wire, cnt = total(entry)
    else:
        out = wire = {k: 0.0 for k in COLL_KINDS}
        cnt = {k: 0 for k in COLL_KINDS}
    return {
        "out_bytes": {k: int(v) for k, v in out.items()},
        "wire_bytes": {k: int(v) for k, v in wire.items()},
        "counts": {k: int(v) for k, v in cnt.items()},
        "total_out_bytes": int(sum(out.values())),
        "total_wire_bytes": int(sum(wire.values())),
    }


def load_hlo(path: str) -> str:
    import zstandard as zstd
    with open(path, "rb") as f:
        return zstd.ZstdDecompressor().decompress(f.read()).decode()
