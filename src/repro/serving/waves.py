"""Shared wave machinery for the serving engines.

Both serving engines -- :class:`~repro.serving.TrajectoryEngine` (whole
offline records) and :class:`~repro.serving.StreamingEngine` (fixed-lag
sliding windows) -- batch work the same way: FIFO waves of exactly
``batch`` rows grouped by padded bucket length, short waves topped up by
recycling a live row, padded rows masked exactly (see
:mod:`repro.core.padding`).  This module is that machinery, factored out
so wave selection, padding/stacking and the wave-level obs metrics have
ONE implementation:

* :class:`WaveItem` -- one queued unit of work (a record or a window
  snapshot), optionally carrying a warm-start trajectory and an
  information-form prior for its left boundary;
* :func:`validate_record` -- shared submit-time shape + time-grid checks
  (strictly-increasing ``ts`` -- a non-monotone grid would silently
  extrapolate a broken padded grid, see :func:`repro.core.padding.pad_record`);
* :func:`merge_measurements` / :func:`insert_warm_states` -- time-ordered
  merge of a late/out-of-order measurement batch into an existing window
  series (drop-before-horizon, duplicate policies, in-window insertion),
  and the matching warm-start-trajectory fix-up;
* :func:`take_wave` -- FIFO wave selection: the oldest item fixes the
  bucket, later same-bucket items top the wave up (continuous batching);
* :func:`pack_wave` -- pad + stack a wave into the arrays of one
  ``Problem.stacked`` solve (measurements, mask, per-row warm starts,
  per-row priors);
* :func:`record_wave_metrics` -- the per-wave obs readout under a metric
  prefix (``engine.*`` / ``stream.*`` -- taxonomy in
  docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.padding import pad_record
from repro.core.registry import get_method


def robust_default_options(method: str):
    """The serving engines' default solver options: the method's defaults
    with the ``discrete`` element mode.

    The core :class:`~repro.core.Estimator` defaults to the paper's
    ``euler`` element mode (explicit Euler on the backward HJB ODEs) --
    faithful to the paper's experiments, but EXPLICIT-EULER-UNSTABLE once
    a block's information Riccati gets stiff (small R / large ``nsub *
    dt``): block elements overflow and the combined estimate silently
    turns NaN (for the test Wiener-velocity model at dt = 0.1 this
    happens from 4 blocks of ``nsub=10`` up).  A serving engine cannot
    pick its record lengths, so it must not default to a mode whose
    stability depends on them: the engines default to the ``discrete``
    mode (exact substep composition -- unconditionally stable, parallel
    == sequential to round-off) and leave ``euler`` opt-in via
    ``options=``.

    Iterated nonlinear methods (``"sigma_point"``) take the ``discrete``
    mode on their INNER method's options -- the outer options keep their
    own defaults (iterations, linearisation family).
    """
    spec = get_method(method)
    if spec.nonlinear:
        outer = spec.options_cls()
        inner = get_method(outer.inner_method).options_cls(mode="discrete")
        return outer.replace(inner=inner)
    return spec.options_cls(mode="discrete")


@dataclasses.dataclass
class WaveItem:
    """One queued unit of work: a whole record or one window snapshot.

    ``key`` is the caller's handle (ticket / track id).  ``x_init`` is an
    optional warm-start trajectory covering the item's real grid
    (``(N+1, nx)``; padded rows repeat the final state).  ``prior`` is an
    optional information-form ``(S0, v0)`` left-boundary override.
    ``seq``/``base`` identify WHICH revision of a mutable source (a
    streaming track) was snapshotted: ``seq`` is the source's mutation
    counter and ``base`` its evicted-interval offset at snapshot time, so
    an apply can be skipped when a newer solve already landed and sliced
    correctly when an older one did.
    """

    key: int
    ts: np.ndarray
    y: np.ndarray
    n_pad: int
    submit_t: float = 0.0          # perf_counter at submit; latency readout
    x_init: Optional[np.ndarray] = None
    prior: Optional[Tuple[np.ndarray, np.ndarray]] = None
    seq: int = 0                   # source mutation counter at snapshot
    base: int = 0                  # source evicted-interval offset at snapshot


@dataclasses.dataclass
class MergeResult:
    """Outcome of :func:`merge_measurements`.

    ``ts``/``y`` are the merged series (fresh arrays whenever anything
    changed -- the inputs are never mutated in place, so snapshots taken
    before the merge stay valid).  ``positions`` are the insertion points
    of the kept NEW measurements w.r.t. the ORIGINAL grid (``np.insert``
    semantics -- feed them to :func:`insert_warm_states` to keep a
    warm-start trajectory aligned).  The counters partition the offered
    batch: ``appended`` (after the old last time), ``merged`` (in-window
    insertions), ``replaced``/``dropped_duplicates`` (duplicate policy),
    ``dropped_late`` (at or before the horizon -- unrepresentable).
    """

    ts: np.ndarray
    y: np.ndarray
    positions: np.ndarray
    appended: int = 0
    merged: int = 0
    replaced: int = 0
    dropped_late: int = 0
    dropped_duplicates: int = 0

    @property
    def changed(self) -> bool:
        """True when the series carries new information (re-solve needed)."""
        return bool(self.appended or self.merged or self.replaced)


DUPLICATE_POLICIES = ("error", "replace", "drop")


def merge_measurements(ts: np.ndarray, y: Optional[np.ndarray],
                       ts_new: np.ndarray, y_new: np.ndarray,
                       *, duplicate: str = "error") -> MergeResult:
    """Merge a sorted batch of measurements into a window series in time
    order.

    ``ts`` is the window grid (``(n+1,)``; ``ts[0]`` is the boundary
    point, measurements sit at ``ts[1:]``) and ``y`` its ``(n, ny)``
    measurements (``None`` for a fresh track).  ``ts_new`` must be
    strictly increasing WITHIN the batch but may land anywhere relative
    to the existing grid:

    * ``t > ts[-1]`` -- appended (the in-order fast path);
    * ``ts[0] < t < ts[-1]``, not on a grid point -- inserted in time
      order (an in-window late measurement);
    * ``t`` exactly on an existing measurement point -- the ``duplicate``
      policy decides: ``"error"`` raises, ``"replace"`` overwrites that
      row, ``"drop"`` ignores it;
    * ``t <= ts[0]`` -- dropped and counted (``ts[0]`` is the committed
      horizon: everything at or before it is already summarised by the
      boundary prior and cannot be represented in the window).
    """
    if duplicate not in DUPLICATE_POLICIES:
        raise ValueError(
            f"duplicate policy must be one of {DUPLICATE_POLICIES}, "
            f"got {duplicate!r}")
    ts = np.asarray(ts)
    ts_new = np.asarray(ts_new, dtype=float)
    y_new = np.asarray(y_new)
    n = ts.shape[0]

    late = ts_new <= ts[0]
    idx = np.searchsorted(ts, ts_new)
    dup = (idx < n) & (ts[np.minimum(idx, n - 1)] == ts_new) & ~late
    if dup.any() and duplicate == "error":
        raise ValueError(
            f"measurements at {ts_new[dup].tolist()} duplicate existing "
            "grid points (duplicate_policy='error'; use 'replace' or "
            "'drop' to accept re-sends)")
    replaced = 0
    if dup.any() and duplicate == "replace":
        y = y.copy()                       # never mutate a snapshotted array
        y[idx[dup] - 1] = y_new[dup]       # measurement for ts[i] is y[i-1]
        replaced = int(dup.sum())

    keep = ~late & ~dup
    positions = idx[keep]
    if keep.any():
        merged = int((ts_new[keep] < ts[-1]).sum())
        ts = np.insert(ts, positions, ts_new[keep])
        rows = y_new[keep]
        y = rows.copy() if y is None else np.insert(y, positions - 1, rows,
                                                    axis=0)
    else:
        merged = 0
    return MergeResult(
        ts=ts, y=y, positions=positions,
        appended=int(keep.sum()) - merged, merged=merged, replaced=replaced,
        dropped_late=int(late.sum()),
        dropped_duplicates=int(dup.sum()) if duplicate == "drop" else 0)


def insert_warm_states(x_warm: np.ndarray,
                       positions: np.ndarray) -> np.ndarray:
    """Keep a warm-start trajectory aligned after in-window insertions:
    each inserted grid point takes its LEFT neighbour's state (the warm
    start is only a linearisation hint, so a zero-order hold is enough).
    ``positions`` are original-grid insertion points (``np.insert``
    semantics, as returned by :func:`merge_measurements`); points past the
    trajectory's end are ignored -- :func:`_pad_trajectory` repeats the
    final state over any un-warmed tail."""
    pos = np.asarray(positions, dtype=int)
    pos = pos[pos <= x_warm.shape[0] - 1]
    if pos.size == 0:
        return x_warm
    return np.insert(x_warm, pos, x_warm[np.maximum(pos - 1, 0)], axis=0)


def validate_record(ts, y) -> Tuple[np.ndarray, np.ndarray]:
    """Shared submit-time validation: shapes and a strictly-increasing
    time grid.  Returns ``(ts, y)`` as numpy arrays."""
    ts = np.asarray(ts)
    y = np.asarray(y)
    if y.ndim != 2 or y.shape[0] < 1:
        raise ValueError(
            f"y must be (N, ny) with N >= 1, got shape {y.shape}")
    if ts.shape != (y.shape[0] + 1,):
        raise ValueError(
            f"ts must be (N+1,) = {(y.shape[0] + 1,)}, got {ts.shape}")
    if not np.all(np.diff(ts) > 0):
        raise ValueError(
            "ts must be strictly increasing (padding extrapolates the "
            f"grid with the final step, which a non-monotone or repeated "
            f"time point would corrupt); got ts={ts!r}")
    return ts, y


def take_wave(queue: Deque[WaveItem], batch: int) -> List[WaveItem]:
    """FIFO wave: the oldest item fixes the bucket; later same-bucket
    items top the wave up to ``batch`` (others keep their place).
    Scanning stops as soon as the wave is full, so draining Q queued
    items is O(Q), not O(Q^2/batch).  Mutates ``queue`` in place."""
    n_pad = queue[0].n_pad
    wave: List[WaveItem] = []
    keep: Deque[WaveItem] = collections.deque()
    while queue and len(wave) < batch:
        item = queue.popleft()
        if item.n_pad == n_pad:
            wave.append(item)
        else:
            keep.append(item)
    keep.extend(queue)                 # untouched tail, order preserved
    queue.clear()
    queue.extend(keep)
    return wave


def _pad_trajectory(x: np.ndarray, n_pad: int) -> np.ndarray:
    """Extend a warm-start trajectory ``(N+1, nx)`` to ``(n_pad+1, nx)``
    by repeating the final state (the padded tail follows the drift from
    there; the repeated point is only a linearisation/warm-start hint)."""
    extra = n_pad + 1 - x.shape[0]
    if extra <= 0:
        return x[:n_pad + 1]
    return np.concatenate([x, np.repeat(x[-1:], extra, axis=0)], axis=0)


def pack_wave(wave: List[WaveItem], batch: int):
    """Pad + stack a same-bucket wave into stacked-problem arrays.

    Returns ``(ts_b, ys_b, mask_b, x_init_b, prior_b)`` with exactly
    ``batch`` rows -- short waves recycle row 0.  ``x_init_b`` is a
    ``(batch, n_pad+1, nx)`` array when ANY item carries a warm start
    (items without one get their prior-mean-free default only if ALL lack
    it -- mixing is resolved by requiring the caller to be consistent);
    ``prior_b`` similarly stacks per-row ``(S0, v0)``.
    """
    n_pad = wave[0].n_pad
    padded = [pad_record(it.ts, it.y, n_pad) for it in wave]
    rows = padded + [padded[0]] * (batch - len(padded))
    ts_b = jnp.asarray(np.stack([r[0] for r in rows]))
    ys_b = jnp.asarray(np.stack([r[1] for r in rows]))
    mask_b = jnp.asarray(np.stack([r[2] for r in rows]))

    x_init_b = None
    if any(it.x_init is not None for it in wave):
        if not all(it.x_init is not None for it in wave):
            raise ValueError(
                "wave mixes items with and without warm-start trajectories")
        xi_rows = [_pad_trajectory(np.asarray(it.x_init), n_pad)
                   for it in wave]
        xi_rows += [xi_rows[0]] * (batch - len(xi_rows))
        x_init_b = jnp.asarray(np.stack(xi_rows))

    prior_b = None
    if any(it.prior is not None for it in wave):
        if not all(it.prior is not None for it in wave):
            raise ValueError(
                "wave mixes items with and without boundary priors")
        S_rows = [np.asarray(it.prior[0]) for it in wave]
        v_rows = [np.asarray(it.prior[1]) for it in wave]
        S_rows += [S_rows[0]] * (batch - len(S_rows))
        v_rows += [v_rows[0]] * (batch - len(v_rows))
        prior_b = (jnp.asarray(np.stack(S_rows)),
                   jnp.asarray(np.stack(v_rows)))
    return ts_b, ys_b, mask_b, x_init_b, prior_b


def record_wave_metrics(prefix: str, wave: List[WaveItem], n_pad: int,
                        batch: int, queue_depth: int) -> None:
    """Per-wave obs readout under ``prefix`` (``engine`` / ``stream``):
    waves/completed/recycled counters, interval-padding accounting, the
    cumulative ``<prefix>.padding_waste`` gauge, wave occupancy, queue
    depth and the per-item submit-to-done latency histogram."""
    now = time.perf_counter()
    real = sum(it.y.shape[0] for it in wave)
    solved = n_pad * batch
    obs.inc(f"{prefix}.waves")
    obs.inc(f"{prefix}.completed", len(wave))
    obs.inc(f"{prefix}.recycled_rows", batch - len(wave))
    obs.inc(f"{prefix}.real_intervals", real)
    obs.inc(f"{prefix}.padded_intervals", solved)
    obs.record(f"{prefix}.wave_occupancy", len(wave) / batch,
               buckets=[i / 20 for i in range(21)])
    # cumulative padding waste: fraction of solved intervals that were
    # padding or recycled rows (0 = perfect packing)
    c = obs.REGISTRY.counter
    total_real = c(f"{prefix}.real_intervals").value
    total_solved = c(f"{prefix}.padded_intervals").value
    if total_solved:
        obs.set_gauge(f"{prefix}.padding_waste",
                      1.0 - total_real / total_solved)
    obs.set_gauge(f"{prefix}.queue_depth", queue_depth)
    latency = ("engine.record_latency_seconds" if prefix == "engine"
               else f"{prefix}.window_latency_seconds")
    for it in wave:
        obs.record(latency, now - it.submit_t)
