"""Request-axis throughput: problems/sec vs batch size.

The paper's axis is time (span log T per problem); production serving also
exploits the REQUEST axis -- many independent estimation problems solved as
one compiled, batched program (``Estimator.solve(Problem.stacked(...))``).
This benchmark reports problems/sec for sequential vs parallel methods
across batch sizes: on accelerators the parallel method keeps per-problem
latency flat while batching multiplies throughput until the device
saturates.  The timed callable is the ahead-of-time ``Estimator.lower(
problem).compile()`` executable -- zero Python dispatch in the loop.

    PYTHONPATH=src python benchmarks/batch_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp


def run(batch_sizes=(1, 8, 32), T=64, nsub=10, mode="discrete",
        methods=("sequential_rts", "parallel_rts"), repeats=3, smoke=False):
    from repro.configs.wiener_velocity import WienerVelocityConfig
    from repro.core import (
        Estimator, Problem, get_method, simulate_linear, time_grid,
    )

    if smoke:
        T, repeats = 8, 1

    wcfg = WienerVelocityConfig(p0=1.0)
    model = wcfg.model()
    N = T * nsub
    ts = time_grid(wcfg.t0, wcfg.tf, N, dtype=jnp.float32)
    _, y = simulate_linear(model, ts, jax.random.PRNGKey(0))

    rows = []
    for method in methods:
        options = get_method(method).options_cls.from_legacy(
            nsub=nsub, mode=mode)
        est = Estimator(model, method=method, options=options)
        for B in batch_sizes:
            ys = jnp.broadcast_to(y, (B,) + y.shape)
            problem = Problem.stacked(model, ts, ys)
            compiled = est.lower(problem).compile()      # AOT: no retrace
            compiled(ts, ys).x.block_until_ready()       # warmup
            t0 = time.perf_counter()
            for _ in range(repeats):
                compiled(ts, ys).x.block_until_ready()
            dt = (time.perf_counter() - t0) / repeats
            rows.append({
                "name": f"batch/{method}/B{B}_T{T}",
                "us_per_call": dt * 1e6,
                "derived": f"problems_per_sec={B / dt:.1f}",
            })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI bit-rot check)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write a BENCH json artifact for this section")
    args = ap.parse_args()
    import repro.obs as obs
    if args.json:
        obs.enable()
        obs.reset()
    rows = run(smoke=args.smoke)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        obs.write_bench_json(
            args.json, obs.bench_record("batch", rows, seeds={"batch": 0}))


if __name__ == "__main__":
    main()
