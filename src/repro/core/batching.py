"""Legacy batched entry points (deprecation shims).

The request-axis layer lives on the unified surface now:

* stacked records -> ``Estimator.solve(Problem.stacked(model, ts, ys))``
* ragged records  -> ``Estimator.solve(Problem.ragged(model, records))``

with the executable cache absorbed into :mod:`repro.core.estimator`
(:func:`~repro.core.estimator.cache_stats` /
:func:`~repro.core.estimator.clear_cache` re-exported here) and the
pad-and-bucket utilities in :mod:`repro.core.padding`.  The functions
below construct the equivalent ``Problem``/``Estimator`` and emit a
``DeprecationWarning``; see ``docs/MIGRATION.md``.
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence, Union

import jax.numpy as jnp

from .estimator import (
    Estimator,
    Problem,
    cache_stats,
    clear_cache,
    legacy_options,
)
from .padding import (
    bucket_length,
    pad_record,
    slice_solution,
)
from .sde import LinearSDE, NonlinearSDE
from .types import Solution

Model = Union[LinearSDE, NonlinearSDE]

# Re-exports: the cache and padding helpers used to live here.
__all__ = [
    "map_estimate_batched", "map_estimate_ragged",
    "Estimator", "Problem", "legacy_options",
    "cache_stats", "clear_cache",
    "bucket_length", "pad_record", "slice_solution",
]


def _legacy_estimator(model, method, nsub, mode, iterations,
                      divergence_correction, mesh, batch_axis) -> Estimator:
    return Estimator(
        model, method=method,
        options=legacy_options(model, method, nsub=nsub, mode=mode,
                               iterations=iterations,
                               divergence_correction=divergence_correction),
        mesh=mesh, batch_axis=batch_axis)


def map_estimate_batched(
    model: Model,
    ts: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
    measurement_mask: Optional[jnp.ndarray] = None,
    mesh=None,
    batch_axis: str = "data",
) -> Solution:
    """Deprecated shim: use ``Estimator(...).solve(Problem.stacked(...))``."""
    warnings.warn(
        "map_estimate_batched is deprecated; use repro.core.Estimator with "
        "Problem.stacked (see docs/MIGRATION.md)",
        DeprecationWarning, stacklevel=2)
    est = _legacy_estimator(model, method, nsub, mode, iterations,
                            divergence_correction, mesh, batch_axis)
    return est.solve(Problem.stacked(model, ts, ys,
                                     measurement_mask=measurement_mask))


def map_estimate_ragged(
    model: Model,
    records: Sequence,
    *,
    method: str = "parallel_rts",
    nsub: int = 10,
    mode: str = "euler",
    iterations: int = 5,
    divergence_correction: bool = False,
    bucket_sizes: Optional[Sequence[int]] = None,
    pad_batch: bool = True,
    mesh=None,
    batch_axis: str = "data",
) -> List[Solution]:
    """Deprecated shim: use ``Estimator(...).solve(Problem.ragged(...))``."""
    warnings.warn(
        "map_estimate_ragged is deprecated; use repro.core.Estimator with "
        "Problem.ragged (see docs/MIGRATION.md)",
        DeprecationWarning, stacklevel=2)
    est = _legacy_estimator(model, method, nsub, mode, iterations,
                            divergence_correction, mesh, batch_axis)
    return est.solve(Problem.ragged(model, records,
                                    bucket_sizes=bucket_sizes,
                                    pad_batch=pad_batch))
