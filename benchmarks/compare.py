"""Benchmark regression gate: diff a fresh ``BENCH_*.json`` against a
committed baseline.

Usage (CI's ``bench-baseline`` job):

  python benchmarks/compare.py BENCH_smoke.json \\
      --against benchmarks/baselines/BENCH_seed.json --tolerance 0.5

Two classes of check, matching how trustworthy each metric is on shared
CPU runners:

* **timing (warn-only by default)** -- per-row ``us_per_call`` ratios.
  Wall time on CI machines is noisy, so a ratio beyond ``1 + tolerance``
  prints a WARN line and does not fail the job.  ``--timing-hard``
  upgrades these to hard failures for quiet dedicated runners.
* **hard (always fail)** -- deterministic structural metrics derived from
  the obs snapshot of the fixed smoke workload:
    - a row present in the baseline but missing from the new run
      (a benchmark section silently disappeared);
    - executable-cache hit rate (``cache.hits / (hits + misses)``)
      dropping by more than ``--hard-tolerance`` (a cache-key or
      retrace regression: the same workload now compiles more);
    - engine padding waste (``engine.padding_waste`` gauge) increasing
      by more than ``--hard-tolerance`` (a bucketing/packing
      regression: the same record mix now solves more padded
      intervals).

Exit status: 0 = pass (possibly with warnings), 1 = hard failure,
2 = unusable input (missing file / schema violation).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))   # repro.obs without PYTHONPATH=src


def _load(path):
    from repro import obs

    try:
        with open(path) as f:
            record = json.load(f)
    except (OSError, ValueError) as e:
        print(f"ERROR: cannot read {path}: {e}")
        return None
    errors = obs.validate_bench(record)
    if errors:
        print(f"ERROR: {path} fails BENCH schema v{obs.SCHEMA_VERSION}:")
        for err in errors:
            print(f"  - {err}")
        return None
    return record


def _counter(record, name):
    return record.get("obs", {}).get("counters", {}).get(name)


def _gauge(record, name):
    return record.get("obs", {}).get("gauges", {}).get(name)


def cache_hit_rate(record):
    hits = _counter(record, "cache.hits")
    misses = _counter(record, "cache.misses")
    if hits is None or misses is None or (hits + misses) == 0:
        return None
    return hits / (hits + misses)


def compare(base, new, *, tolerance, hard_tolerance, timing_hard=False):
    """Return (hard_failures, warnings) as lists of message strings."""
    hard, warn = [], []

    base_rows = {r["name"]: r for r in base["rows"]}
    new_rows = {r["name"]: r for r in new["rows"]}
    for name in sorted(base_rows):
        if name not in new_rows:
            hard.append(f"row missing from new run: {name}")
            continue
        b, n = base_rows[name]["us_per_call"], new_rows[name]["us_per_call"]
        if b > 0 and n > b * (1.0 + tolerance):
            msg = (f"timing regression {name}: {b:.1f} -> {n:.1f} us/call "
                   f"({n / b:.2f}x, tolerance {1.0 + tolerance:.2f}x)")
            (hard if timing_hard else warn).append(msg)

    base_hr, new_hr = cache_hit_rate(base), cache_hit_rate(new)
    if base_hr is not None and new_hr is not None:
        if new_hr < base_hr - hard_tolerance:
            hard.append(
                f"cache hit rate dropped: {base_hr:.3f} -> {new_hr:.3f} "
                f"(allowed drop {hard_tolerance})")
    elif base_hr is not None:
        hard.append("cache hit/miss counters missing from new run")

    base_w, new_w = (_gauge(base, "engine.padding_waste"),
                     _gauge(new, "engine.padding_waste"))
    if base_w is not None and new_w is not None:
        if new_w > base_w + hard_tolerance:
            hard.append(
                f"engine padding waste increased: {base_w:.3f} -> "
                f"{new_w:.3f} (allowed increase {hard_tolerance})")
    elif base_w is not None:
        hard.append("engine.padding_waste gauge missing from new run")

    return hard, warn


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff a BENCH json against a committed baseline")
    ap.add_argument("new", help="fresh BENCH_*.json from this run")
    ap.add_argument("--against", required=True,
                    help="committed baseline BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional us_per_call increase "
                         "(default 0.5 = 1.5x; warn-only unless "
                         "--timing-hard)")
    ap.add_argument("--hard-tolerance", type=float, default=0.02,
                    help="allowed absolute drop in cache hit rate / "
                         "increase in padding waste (default 0.02)")
    ap.add_argument("--timing-hard", action="store_true",
                    help="fail (not warn) on timing regressions -- for "
                         "quiet dedicated runners")
    args = ap.parse_args(argv)

    base = _load(args.against)
    new = _load(args.new)
    if base is None or new is None:
        return 2

    hard, warn = compare(base, new, tolerance=args.tolerance,
                         hard_tolerance=args.hard_tolerance,
                         timing_hard=args.timing_hard)
    for msg in warn:
        print(f"WARN: {msg}")
    for msg in hard:
        print(f"FAIL: {msg}")
    n_rows = len(base["rows"])
    if hard:
        print(f"compare: {len(hard)} hard failure(s), "
              f"{len(warn)} warning(s) over {n_rows} baseline rows")
        return 1
    print(f"compare: OK ({n_rows} baseline rows, {len(warn)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
