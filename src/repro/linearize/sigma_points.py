"""Sigma-point families: unit weight/point generation for SLR.

Every family generates points for the STANDARD normal in R^n (unit
points); :mod:`repro.linearize.slr` shifts/scales them through the
Cholesky factor of the actual spread covariance.  Generation is
host-side numpy on static shapes (the state dimension and family
parameters are compile-time constants), memoised per ``(family, n)``,
and converted to the caller's dtype at use -- so SLR is safe under
``jit``/``vmap``/``lax.scan`` and never bakes a stale-dtype constant.

Families (S = point count for state dimension n):

* :class:`Unscented` -- ``2n + 1`` points (Julier-Uhlmann UT with the
  ``alpha``/``beta``/``kappa`` parametrisation).  The default
  ``kappa=0`` keeps every weight non-negative for all n (the classic
  ``kappa = 3 - n`` goes negative for n > 3, which can make the SLR
  residual covariance indefinite).
* :class:`Cubature` -- ``2n`` points (third-degree spherical-radial
  rule; the UT with the centre point dropped).
* :class:`GaussHermite` -- ``order**n`` tensor-product Gauss-Hermite
  points (exact for polynomials up to degree ``2*order - 1`` per axis;
  exponential in n -- use for small state dimensions).

All weight vectors satisfy ``sum(wm) == 1`` (mean consistency) and
reproduce the first two moments of the generating Gaussian to machine
precision -- pinned by the property tests in
``tests/test_linearize_properties.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import NamedTuple, Optional

import numpy as np


class SigmaPoints(NamedTuple):
    """Unit sigma points for the standard normal in R^n (host arrays)."""

    points: np.ndarray  # (S, n) unit-space points
    wm: np.ndarray      # (S,) mean weights, sum to 1
    wc: np.ndarray      # (S,) covariance weights


@dataclasses.dataclass(frozen=True)
class SigmaPointFamily:
    """Base class: a hashable, frozen description of one point rule."""

    def build(self, n: int) -> SigmaPoints:
        raise NotImplementedError

    def num_points(self, n: int) -> int:
        raise NotImplementedError

    @property
    def name(self) -> str:
        return type(self).__name__.lower()


@dataclasses.dataclass(frozen=True)
class Unscented(SigmaPointFamily):
    """Unscented transform points (2n + 1).

    ``lambda = alpha^2 (n + kappa) - n`` must satisfy ``n + lambda > 0``;
    ``kappa=None`` resolves to the all-weights-non-negative ``0.0``
    default (pass ``3 - n`` for the classic heuristic).
    """

    alpha: float = 1.0
    beta: float = 0.0
    kappa: Optional[float] = None

    def __post_init__(self) -> None:
        if not (isinstance(self.alpha, (int, float)) and self.alpha > 0):
            raise ValueError(f"alpha must be > 0, got {self.alpha!r}")
        if not isinstance(self.beta, (int, float)):
            raise ValueError(f"beta must be a float, got {self.beta!r}")
        if self.kappa is not None and not isinstance(self.kappa,
                                                     (int, float)):
            raise ValueError(
                f"kappa must be None (auto) or a float, got {self.kappa!r}")

    def build(self, n: int) -> SigmaPoints:
        kappa = 0.0 if self.kappa is None else float(self.kappa)
        lam = self.alpha ** 2 * (n + kappa) - n
        if n + lam <= 0:
            raise ValueError(
                f"unscented scaling n + lambda must be > 0; got "
                f"n={n}, alpha={self.alpha}, kappa={kappa} "
                f"(lambda={lam})")
        s = np.sqrt(n + lam)
        pts = np.concatenate(
            [np.zeros((1, n)), s * np.eye(n), -s * np.eye(n)], axis=0)
        wi = 1.0 / (2.0 * (n + lam))
        wm = np.full(2 * n + 1, wi)
        wm[0] = lam / (n + lam)
        wc = wm.copy()
        wc[0] += 1.0 - self.alpha ** 2 + self.beta
        return SigmaPoints(pts, wm, wc)

    def num_points(self, n: int) -> int:
        return 2 * n + 1


@dataclasses.dataclass(frozen=True)
class Cubature(SigmaPointFamily):
    """Third-degree spherical-radial cubature points (2n)."""

    def build(self, n: int) -> SigmaPoints:
        s = np.sqrt(float(n))
        pts = np.concatenate([s * np.eye(n), -s * np.eye(n)], axis=0)
        w = np.full(2 * n, 1.0 / (2 * n))
        return SigmaPoints(pts, w, w.copy())

    def num_points(self, n: int) -> int:
        return 2 * n


@dataclasses.dataclass(frozen=True)
class GaussHermite(SigmaPointFamily):
    """Tensor-product Gauss-Hermite points (``order**n``)."""

    order: int = 3

    def __post_init__(self) -> None:
        # order 1 is the single midpoint: it cannot reproduce a
        # covariance, which SLR's regression divides by -- require the
        # first order whose quadrature matches second moments.
        if not isinstance(self.order, int) or self.order < 2:
            raise ValueError(
                f"order must be an int >= 2, got {self.order!r}")

    def build(self, n: int) -> SigmaPoints:
        if self.order ** n > 200_000:
            raise ValueError(
                f"gauss_hermite(order={self.order}) needs {self.order}**{n} "
                f"= {self.order ** n} points for nx={n}; use a lower order "
                f"or the unscented/cubature families")
        # probabilists' Hermite quadrature: weight exp(-x^2/2), total
        # mass sqrt(2 pi) -- normalise so the 1-D weights sum to 1.
        x1, w1 = np.polynomial.hermite_e.hermegauss(self.order)
        w1 = w1 / np.sqrt(2.0 * np.pi)
        idx = list(itertools.product(range(self.order), repeat=n))
        pts = np.asarray([[x1[i] for i in multi] for multi in idx])
        w = np.asarray([np.prod([w1[i] for i in multi]) for multi in idx])
        return SigmaPoints(pts.reshape(len(idx), n), w, w.copy())

    def num_points(self, n: int) -> int:
        return self.order ** n


@functools.lru_cache(maxsize=None)
def unit_points(family: SigmaPointFamily, n: int) -> SigmaPoints:
    """Memoised host-side generation: families are frozen/hashable, so
    one ``(family, n)`` pair is built exactly once per process."""
    return family.build(n)
