"""Iterated (parallel) MAP estimation on the coordinated-turn model (5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    iterated_map, om_cost_nonlinear, simulate_nonlinear, time_grid,
)

from helpers import coordinated_turn


@pytest.fixture(scope="module")
def ct_problem():
    model = coordinated_turn()
    N = 640
    ts = time_grid(0.0, 5.0, N)
    xs, y = simulate_nonlinear(model, ts, jax.random.PRNGKey(2))
    return model, ts, xs, y


def test_parallel_equals_sequential_ieks(ct_problem):
    model, ts, _, y = ct_problem
    par = iterated_map(model, ts, y, iterations=5, method="parallel_rts",
                       nsub=10, mode="discrete")
    seq = iterated_map(model, ts, y, iterations=5, method="sequential_rts",
                       mode="discrete")
    np.testing.assert_allclose(par.x, seq.x, rtol=1e-8, atol=1e-8)


def test_ieks_reduces_om_cost(ct_problem):
    model, ts, _, y = ct_problem
    x0 = jnp.broadcast_to(model.m0, (len(ts), 5))
    c_prev = float(om_cost_nonlinear(model, ts, y, x0))
    for it in (1, 3, 5):
        sol = iterated_map(model, ts, y, iterations=it,
                           method="parallel_rts", nsub=10, mode="discrete")
        c = float(om_cost_nonlinear(model, ts, y, sol.x))
        assert c < c_prev * 1.0001, (it, c, c_prev)
        c_prev = c


def test_ieks_tracks_truth(ct_problem):
    model, ts, xs, y = ct_problem
    sol = iterated_map(model, ts, y, iterations=5, method="parallel_rts",
                       nsub=10, mode="discrete")
    rmse = float(jnp.sqrt(jnp.mean((sol.x[:, :2] - xs[:, :2]) ** 2)))
    # positions are observed through (range, bearing) with tight noise
    assert rmse < 0.5, rmse


def test_euler_mode_ieks(ct_problem):
    model, ts, _, y = ct_problem
    par = iterated_map(model, ts, y, iterations=3, method="parallel_rts",
                       nsub=10, mode="euler")
    seq = iterated_map(model, ts, y, iterations=3, method="sequential_rts",
                       mode="euler")
    assert float(jnp.max(jnp.abs(par.x - seq.x))) < 5e-2


def test_divergence_correction_runs(ct_problem):
    """the beyond-paper Onsager-Machlup divergence knob must run and stay
    close to the uncorrected solution (div f = 0 for coordinated turn!)."""
    model, ts, _, y = ct_problem
    a = iterated_map(model, ts, y, iterations=2, method="parallel_rts",
                     nsub=10, mode="discrete")
    b = iterated_map(model, ts, y, iterations=2, method="parallel_rts",
                     nsub=10, mode="discrete", divergence_correction=True)
    # f = (v, -w zdot, w xidot, 0): div f = d(-w zdot)/dzdot ... = 0 + w - w = 0
    np.testing.assert_allclose(a.x, b.x, rtol=1e-7, atol=1e-7)


def test_two_filter_ieks(ct_problem):
    model, ts, _, y = ct_problem
    rts = iterated_map(model, ts, y, iterations=3, method="parallel_rts",
                       nsub=10, mode="discrete")
    tf = iterated_map(model, ts, y, iterations=3,
                      method="parallel_two_filter", nsub=10, mode="discrete")
    np.testing.assert_allclose(tf.x, rts.x, rtol=1e-5, atol=1e-5)
