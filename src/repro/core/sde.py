"""Continuous-time state-space models, grid discretisation and simulation.

Implements the model classes of eq. (1)/(12), the time reversal of section
2.2 producing the :class:`~repro.core.types.GridLQT` problem, Euler-Maruyama
simulation for generating synthetic data, and the (discretised)
Onsager-Machlup cost functional of eq. (2).

Grid conventions (see DESIGN.md S1 and tests/test_oracle.py):

* original time grid ``t_k = t0 + k dt`` for ``k = 0..N``; coefficient /
  measurement index ``k`` covers ``[t_k, t_{k+1}]``;
* the reversed problem has ``phi_j = x(t_{N-j})``; reversed interval ``j``
  maps to original interval ``k = N-1-j`` and its Euler step evaluates the
  drift at the reversed-left point ``phi_j = x_{k+1}`` (backward-Euler in
  original time);
* continuous-time measurement noise with spectral density R discretises to
  ``y_k ~ N(h(x), R/dt)`` so that ``dt * y_k^T R^{-1} y_k`` is the correct
  quadrature of the Onsager-Machlup measurement integral.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .types import GridLQT


Array = jnp.ndarray

# Information-form prior override (S0, v0): the initial boundary enters the
# reversed LQT as terminal information S_T = S0, v_T = v0 (= P0^{-1},
# P0^{-1} m0 for a covariance-form prior).  Fixed-lag streaming hands the
# forward-filter information at a window's left edge through this -- see
# docs/STREAMING.md.
Prior = Tuple[Array, Array]


@dataclasses.dataclass(frozen=True)
class LinearSDE:
    """Linear-affine model (eq. 12), possibly time-varying via callables.

    ``F, c, H, r, Q, R`` may each be a constant array or a callable of t.
    ``Q = L W L^T`` must be invertible (paper assumption, section 2.1).
    """

    F: Array | Callable[[Array], Array]
    c: Array | Callable[[Array], Array]
    H: Array | Callable[[Array], Array]
    r: Array | Callable[[Array], Array]
    Q: Array | Callable[[Array], Array]
    R: Array | Callable[[Array], Array]
    m0: Array
    P0: Array

    @property
    def nx(self) -> int:
        return self.m0.shape[-1]

    @property
    def ny(self) -> Optional[int]:
        """Measurement dimension, or ``None`` when ``R`` is time-varying
        (a callable) and the dimension is not statically known."""
        return None if callable(self.R) else jnp.asarray(self.R).shape[-1]

    def _eval(self, item, ts):
        if callable(item):
            return jax.vmap(item)(ts)
        arr = jnp.asarray(item)
        return jnp.broadcast_to(arr, ts.shape + arr.shape)

    def grids(self, ts: Array):
        """Evaluate all coefficients on the left points of the N intervals."""
        tl = ts[:-1]
        return (
            self._eval(self.F, tl),
            self._eval(self.c, tl),
            self._eval(self.H, tl),
            self._eval(self.r, tl),
            self._eval(self.Q, tl),
            self._eval(self.R, tl),
        )


@dataclasses.dataclass(frozen=True)
class NonlinearSDE:
    """Nonlinear model (eq. 1): drift f(x, t), observation h(x, t)."""

    f: Callable[[Array, Array], Array]
    h: Callable[[Array, Array], Array]
    Q: Array | Callable[[Array], Array]
    R: Array | Callable[[Array], Array]
    m0: Array
    P0: Array

    @property
    def nx(self) -> int:
        return self.m0.shape[-1]

    @property
    def ny(self) -> Optional[int]:
        """Measurement dimension, or ``None`` when ``R`` is time-varying
        (a callable) and the dimension is not statically known."""
        return None if callable(self.R) else jnp.asarray(self.R).shape[-1]

    def _eval(self, item, ts):
        if callable(item):
            return jax.vmap(item)(ts)
        arr = jnp.asarray(item)
        return jnp.broadcast_to(arr, ts.shape + arr.shape)

    def linearise(self, xbar: Array, ts: Array):
        """First-order Taylor expansion about a nominal trajectory.

        Returns grid arrays (F, c, H, r) with ``f(x,t) ~= F x + c`` and
        ``h(x,t) ~= H x + r`` at each interval left point (section 4.4).
        Delegates to :mod:`repro.linearize.taylor`, which holds the same
        jacfwd-vmap computation this method used to inline.
        """
        from repro.linearize.taylor import taylor_linearize_grid

        tl = ts[:-1]
        xb = xbar[:-1]
        F, c = taylor_linearize_grid(self.f, xb, tl)
        H, r = taylor_linearize_grid(self.h, xb, tl)
        return F, c, H, r

    def divergence_gradient(self, xbar: Array, ts: Array) -> Array:
        """grad_x (div f)(xbar, t): the linearised Onsager-Machlup
        divergence correction (optional, DESIGN.md S1)."""
        tl = ts[:-1]
        xb = xbar[:-1]

        def div_f(x, t):
            return jnp.trace(jax.jacfwd(self.f, argnums=0)(x, t))

        return jax.vmap(jax.grad(div_f, argnums=0))(xb, tl)


def time_grid(t0: float, tf: float, num_steps: int, dtype=jnp.float64) -> Array:
    return jnp.linspace(t0, tf, num_steps + 1, dtype=dtype)


def build_grid_lqt(
    F: Array, c: Array, H: Array, r: Array, Q: Array, R: Array,
    y: Array, dt: Array, m0: Array, P0: Array,
    lin: Optional[Array] = None,
    measurement_mask: Optional[Array] = None,
    prior: Optional[Prior] = None,
) -> GridLQT:
    """Time-reverse grid coefficients into the LQT problem of section 2.4.

    Reversed interval ``j`` <- original interval ``N-1-j``;
    ``F~ = -F``, ``c~ = -c`` (section 2.2 definitions).

    ``measurement_mask`` (``(N,)``, original time order, 1.0 = real) zeroes
    ``R^{-1}`` (and the optional linear cost) on masked intervals, removing
    their measurement information while keeping the dynamics prior.  A
    masked tail beyond the last real measurement contributes zero cost at
    the optimum (the extension just follows the drift), so the MAP estimate
    at real points is unchanged -- the basis of exact length-padding in
    :mod:`repro.core.batching`.

    ``prior`` ``(S0, v0)`` replaces the covariance-form ``(m0, P0)``
    boundary with information-form terminal values directly (no inversion):
    fixed-lag window solves pass the forward-filter information at the
    window's left edge here, which makes the window solve exactly the full
    MAP restricted to the window (docs/STREAMING.md).
    """
    flip = lambda a: jnp.flip(a, axis=0)
    Rinv = jnp.linalg.inv(R)
    if measurement_mask is not None:
        Rinv = Rinv * measurement_mask[:, None, None]
        if lin is not None:
            lin = lin * measurement_mask[:, None]
    if prior is not None:
        S_T, v_T = jnp.asarray(prior[0]), jnp.asarray(prior[1])
    else:
        S_T = jnp.linalg.inv(P0)
        v_T = S_T @ m0
    return GridLQT(
        dt=flip(jnp.broadcast_to(dt, y.shape[:1])),
        F=-flip(F), c=-flip(c),
        H=flip(H), r=flip(r),
        Q=flip(Q), Rinv=flip(Rinv), y=flip(y),
        S_T=S_T, v_T=v_T,
        lin=None if lin is None else flip(lin),
    )


def grid_lqt_from_linear(
    model: LinearSDE, ts: Array, y: Array,
    measurement_mask: Optional[Array] = None,
    prior: Optional[Prior] = None,
) -> GridLQT:
    F, c, H, r, Q, R = model.grids(ts)
    dt = jnp.diff(ts)
    return build_grid_lqt(F, c, H, r, Q, R, y, dt, model.m0, model.P0,
                          measurement_mask=measurement_mask, prior=prior)


def grid_lqt_from_nonlinear(
    model: NonlinearSDE, ts: Array, y: Array, xbar: Array,
    divergence_correction: bool = False,
    measurement_mask: Optional[Array] = None,
    prior: Optional[Prior] = None,
    linearization=None,
) -> GridLQT:
    """Linearise the nonlinear model about ``xbar`` and time-reverse into
    the grid LQT problem.

    ``linearization`` selects the strategy (``None``/"taylor" = the
    Jacobian path, unchanged from before the subsystem existed).  SLR
    strategies return a residual covariance per grid point, folded into
    the noise as ``Q + Omega_f`` / ``R + Omega_h`` -- the
    posterior-linearisation construction; their spread covariance is the
    model's ``P0`` (scaled by the strategy's ``spread``), a fixed proxy
    until posterior covariances are plumbed through.
    """
    from repro.linearize import get_linearization

    lin_strategy = get_linearization(linearization)
    tl = ts[:-1]
    Q = model._eval(model.Q, tl)
    R = model._eval(model.R, tl)
    if not lin_strategy.has_residual:
        F, c, H, r = model.linearise(xbar, ts)
    else:
        xb = xbar[:-1]
        covs = jnp.broadcast_to(model.P0, xb.shape[:1] + model.P0.shape)
        F, c, Of = lin_strategy.linearize_grid(model.f, xb, tl, covs)
        H, r, Oh = lin_strategy.linearize_grid(model.h, xb, tl, covs)
        Q = Q + Of
        R = R + Oh
    dt = jnp.diff(ts)
    lin = None
    if divergence_correction:
        # Onsager-Machlup adds +1/2 int div f dt; linearised about xbar the
        # phi-dependent part is  1/2 g(xbar)^T phi with g = grad div f.
        lin = 0.5 * model.divergence_gradient(xbar, ts)
    return build_grid_lqt(F, c, H, r, Q, R, y, dt, model.m0, model.P0,
                          lin=lin, measurement_mask=measurement_mask,
                          prior=prior)


# ---------------------------------------------------------------------------
# Simulation + cost functional
# ---------------------------------------------------------------------------


def _psd_sqrt(Q):
    """Matrix square root of a (possibly singular) PSD matrix via eigh --
    Q = L W L^T is singular for most physical models (paper section 2.1
    allows this; only simulation needs a noise square root)."""
    w, V = jnp.linalg.eigh(Q)
    return V @ jnp.diag(jnp.sqrt(jnp.clip(w, 0.0))) @ V.T


def simulate_linear(model: LinearSDE, ts: Array, key: jax.Array):
    """Euler-Maruyama simulation of (12) + discretised measurements."""
    F, c, H, r, Q, R = model.grids(ts)
    dt = jnp.diff(ts)
    kx, ky, k0 = jax.random.split(key, 3)
    x0 = model.m0 + jnp.linalg.cholesky(model.P0) @ jax.random.normal(
        k0, model.m0.shape, dtype=model.m0.dtype)

    def step(x, inp):
        Fk, ck, Qk, dtk, eps = inp
        xn = x + dtk * (Fk @ x + ck) + jnp.sqrt(dtk) * (
            _psd_sqrt(Qk) @ eps)
        return xn, xn

    eps = jax.random.normal(kx, (dt.shape[0],) + model.m0.shape,
                            dtype=model.m0.dtype)
    _, xs = jax.lax.scan(step, x0, (F, c, Q, dt, eps))
    xs = jnp.concatenate([x0[None], xs], axis=0)

    ny = H.shape[-2]
    noise = jax.random.normal(ky, (dt.shape[0], ny), dtype=model.m0.dtype)
    Rch = jnp.linalg.cholesky(R)
    # measurement for interval k uses the reversed-left point x_{k+1}
    # (backward-Euler convention, see module docstring)
    y = (jnp.einsum("kij,kj->ki", H, xs[1:]) + r
         + jnp.einsum("kij,kj->ki", Rch, noise) / jnp.sqrt(dt)[:, None])
    return xs, y


def simulate_nonlinear(model: NonlinearSDE, ts: Array, key: jax.Array):
    dt = jnp.diff(ts)
    tl = ts[:-1]
    Q = model._eval(model.Q, tl)
    R = model._eval(model.R, tl)
    kx, ky, k0 = jax.random.split(key, 3)
    x0 = model.m0 + jnp.linalg.cholesky(model.P0) @ jax.random.normal(
        k0, model.m0.shape, dtype=model.m0.dtype)

    def step(x, inp):
        t, Qk, dtk, eps = inp
        xn = x + dtk * model.f(x, t) + jnp.sqrt(dtk) * (
            _psd_sqrt(Qk) @ eps)
        return xn, xn

    eps = jax.random.normal(kx, (dt.shape[0],) + model.m0.shape,
                            dtype=model.m0.dtype)
    _, xs = jax.lax.scan(step, x0, (tl, Q, dt, eps))
    xs = jnp.concatenate([x0[None], xs], axis=0)

    hx = jax.vmap(model.h)(xs[1:], tl)
    Rch = jnp.linalg.cholesky(R)
    noise = jax.random.normal(ky, hx.shape, dtype=model.m0.dtype)
    y = hx + jnp.einsum("kij,kj->ki", Rch, noise) / jnp.sqrt(dt)[:, None]
    return xs, y


def _prior_cost(model, x0: Array, prior: Optional[Prior]) -> Array:
    """Initial-boundary cost 1/2 (x0 - m)^T P^{-1} (x0 - m), from the
    model's covariance-form prior or an information-form override."""
    if prior is not None:
        S0, v0 = prior
        d0 = x0 - jnp.linalg.solve(S0, v0)
        return 0.5 * d0 @ S0 @ d0
    d0 = x0 - model.m0
    return 0.5 * d0 @ jnp.linalg.solve(model.P0, d0)


def om_cost_linear(model: LinearSDE, ts: Array, y: Array, x: Array,
                   measurement_mask: Optional[Array] = None,
                   prior: Optional[Prior] = None) -> Array:
    """Discretised Onsager-Machlup / minimum-energy cost of a trajectory.

    Uses the backward-Euler quadrature matching the reversed-time solvers
    (drift and measurement evaluated at ``x_{k+1}``); the divergence term is
    constant for linear models and omitted (it cannot change the argmin).
    ``measurement_mask`` (``(N,)`` of 0/1) zeroes the measurement term on
    masked intervals, matching the solvers' missing-data semantics.
    ``prior`` ``(S0, v0)`` replaces the initial-boundary term with the
    information-form prior (fixed-lag window solves).
    """
    F, c, H, r, Q, R = model.grids(ts)
    dt = jnp.diff(ts)
    cost = _prior_cost(model, x[0], prior)
    xr = x[1:]
    resid = (x[1:] - x[:-1]) / dt[:, None] - (
        jnp.einsum("kij,kj->ki", F, xr) + c)
    cost = cost + 0.5 * jnp.sum(
        dt * jnp.einsum("ki,kij,kj->k", resid, jnp.linalg.inv(Q), resid))
    innov = y - (jnp.einsum("kij,kj->ki", H, xr) + r)
    meas = jnp.einsum("ki,kij,kj->k", innov, jnp.linalg.inv(R), innov)
    if measurement_mask is not None:
        meas = meas * measurement_mask
    cost = cost + 0.5 * jnp.sum(dt * meas)
    return cost


def om_cost_nonlinear(
    model: NonlinearSDE, ts: Array, y: Array, x: Array,
    divergence_correction: bool = False,
    measurement_mask: Optional[Array] = None,
    prior: Optional[Prior] = None,
) -> Array:
    dt = jnp.diff(ts)
    tl = ts[:-1]
    Q = model._eval(model.Q, tl)
    R = model._eval(model.R, tl)
    cost = _prior_cost(model, x[0], prior)
    xr = x[1:]
    fx = jax.vmap(model.f)(xr, tl)
    resid = (x[1:] - x[:-1]) / dt[:, None] - fx
    cost = cost + 0.5 * jnp.sum(
        dt * jnp.einsum("ki,kij,kj->k", resid, jnp.linalg.inv(Q), resid))
    innov = y - jax.vmap(model.h)(xr, tl)
    meas = jnp.einsum("ki,kij,kj->k", innov, jnp.linalg.inv(R), innov)
    if measurement_mask is not None:
        meas = meas * measurement_mask
    cost = cost + 0.5 * jnp.sum(dt * meas)
    if divergence_correction:
        def div_f(xk, t):
            return jnp.trace(jax.jacfwd(model.f, argnums=0)(xk, t))
        cost = cost + 0.5 * jnp.sum(dt * jax.vmap(div_f)(xr, tl))
    return cost


def om_cost_grid(grid: GridLQT, x: Array) -> Array:
    """Onsager-Machlup cost of trajectory ``x`` under a built grid problem.

    ``x`` is in ORIGINAL time order (``(N+1, nx)``); the quadrature is the
    reversed-time backward-Euler one the solvers minimise, so this is the
    objective value of a :class:`~repro.core.types.MAPSolution`.  Any
    measurement mask is already folded into ``grid.Rinv`` (masked
    intervals cost nothing).  ``Q`` may be singular (``Q = L W L^T``):
    the dynamics term uses the pseudo-inverse, i.e. the minimum-energy
    cost over noise directions the model actually drives -- identical to
    ``inv(Q)`` whenever ``Q`` is invertible.
    """
    phi = jnp.flip(x, axis=0)                     # phi_j = x_{N-j}
    dt = grid.dt
    resid = (phi[1:] - phi[:-1]) / dt[:, None] - (
        jnp.einsum("kij,kj->ki", grid.F, phi[:-1]) + grid.c)
    Qpinv = jnp.linalg.pinv(grid.Q)
    cost = 0.5 * jnp.sum(
        dt * jnp.einsum("ki,kij,kj->k", resid, Qpinv, resid))
    innov = grid.y - (jnp.einsum("kij,kj->ki", grid.H, phi[:-1]) + grid.r)
    cost = cost + 0.5 * jnp.sum(
        dt * jnp.einsum("ki,kij,kj->k", innov, grid.Rinv, innov))
    if grid.lin is not None:
        cost = cost + jnp.sum(dt * jnp.einsum("ki,ki->k", grid.lin, phi[:-1]))
    # terminal (reversed) boundary = the initial prior N(m0, P0)
    m0 = jnp.linalg.solve(grid.S_T, grid.v_T)
    d0 = phi[-1] - m0
    return cost + 0.5 * d0 @ grid.S_T @ d0
